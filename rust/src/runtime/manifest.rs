//! Typed view of `artifacts/manifest.json` (written by python/compile/aot.py):
//! model configs, parameter layouts, recipe descriptions, and the artifact
//! table with input/output shapes.

use std::collections::HashMap;
use std::path::Path;

use anyhow::{anyhow, Context, Result};

use crate::util::json::Json;

#[derive(Clone, Debug, PartialEq)]
pub struct ShapeEntry {
    pub shape: Vec<usize>,
    pub dtype: String,
}

impl ShapeEntry {
    fn from_json(j: &Json) -> Result<ShapeEntry> {
        let shape = j
            .get("shape")
            .and_then(|s| s.as_arr())
            .ok_or_else(|| anyhow!("shape missing"))?
            .iter()
            .map(|d| d.as_usize().unwrap_or(0))
            .collect();
        let dtype = j.get("dtype").and_then(|d| d.as_str()).unwrap_or("float32").to_string();
        Ok(ShapeEntry { shape, dtype })
    }

    pub fn numel(&self) -> usize {
        self.shape.iter().product()
    }
}

#[derive(Clone, Debug)]
pub struct ParamEntry {
    pub name: String,
    pub shape: Vec<usize>,
    pub dtype: String,
}

#[derive(Clone, Debug)]
pub struct ModelInfo {
    pub name: String,
    pub family: String,
    pub vocab: usize,
    pub layers: usize,
    pub d_model: usize,
    pub n_head: usize,
    pub d_ff: usize,
    pub seq: usize,
    pub param_count: usize,
    /// Flat, name-sorted parameter layout — the AOT argument order.
    pub params: Vec<ParamEntry>,
}

#[derive(Clone, Debug)]
pub struct RecipeSpec {
    pub attn: String,
    pub ffn: String,
    pub wgrad: String,
    pub agrad: String,
    pub granularity: String,
}

#[derive(Clone, Debug)]
pub struct ArtifactMeta {
    pub file: String,
    pub model: String,
    pub recipe: String,
    pub step: String,
    pub use_pallas: bool,
    pub inputs: Vec<ShapeEntry>,
    pub outputs: Vec<ShapeEntry>,
    pub sha256: String,
}

#[derive(Clone, Debug)]
pub struct Manifest {
    pub batch: usize,
    pub total_steps: u64,
    pub models: HashMap<String, ModelInfo>,
    pub recipes: HashMap<String, RecipeSpec>,
    pub table2_rows: Vec<String>,
    pub artifacts: Vec<ArtifactMeta>,
}

impl Manifest {
    pub fn load(path: &Path) -> Result<Manifest> {
        let src = std::fs::read_to_string(path)
            .with_context(|| format!("reading {}", path.display()))?;
        Self::parse(&src)
    }

    pub fn parse(src: &str) -> Result<Manifest> {
        let j = Json::parse(src).map_err(|e| anyhow!("manifest json: {e}"))?;
        let batch = j.get("batch").and_then(|b| b.as_usize()).ok_or_else(|| anyhow!("batch"))?;
        let total_steps = j.get("total_steps").and_then(|b| b.as_i64()).unwrap_or(0) as u64;

        let mut models = HashMap::new();
        for (name, m) in j.get("models").and_then(|m| m.members()).unwrap_or(&[]) {
            let params = m
                .get("params")
                .and_then(|p| p.as_arr())
                .unwrap_or(&[])
                .iter()
                .map(|p| {
                    Ok(ParamEntry {
                        name: p.get("name").and_then(|n| n.as_str()).unwrap_or("").to_string(),
                        shape: ShapeEntry::from_json(p)?.shape,
                        dtype: p.get("dtype").and_then(|d| d.as_str()).unwrap_or("float32").to_string(),
                    })
                })
                .collect::<Result<Vec<_>>>()?;
            let g = |k: &str| m.get(k).and_then(|v| v.as_usize()).unwrap_or(0);
            models.insert(
                name.clone(),
                ModelInfo {
                    name: name.clone(),
                    family: m.get("family").and_then(|f| f.as_str()).unwrap_or("gpt2").to_string(),
                    vocab: g("vocab"),
                    layers: g("layers"),
                    d_model: g("d_model"),
                    n_head: g("n_head"),
                    d_ff: g("d_ff"),
                    seq: g("seq"),
                    param_count: g("param_count"),
                    params,
                },
            );
        }

        let mut recipes = HashMap::new();
        for (name, r) in j.get("recipes").and_then(|m| m.members()).unwrap_or(&[]) {
            let spec = |k: &str| -> (String, String) {
                let fmt = r.at(&[k, "fmt"]).and_then(|v| v.as_str()).unwrap_or("none").to_string();
                let gran = r.at(&[k, "granularity"]).and_then(|v| v.as_str()).unwrap_or("block").to_string();
                (fmt, gran)
            };
            let (attn, gran) = spec("attn");
            recipes.insert(
                name.clone(),
                RecipeSpec {
                    attn,
                    ffn: spec("ffn").0,
                    wgrad: spec("wgrad").0,
                    agrad: spec("agrad").0,
                    granularity: gran,
                },
            );
        }

        let table2_rows = j
            .get("table2_rows")
            .and_then(|a| a.as_arr())
            .unwrap_or(&[])
            .iter()
            .filter_map(|v| v.as_str().map(String::from))
            .collect();

        let mut artifacts = Vec::new();
        for a in j.get("artifacts").and_then(|a| a.as_arr()).unwrap_or(&[]) {
            let shapes = |k: &str| -> Result<Vec<ShapeEntry>> {
                a.get(k)
                    .and_then(|x| x.as_arr())
                    .unwrap_or(&[])
                    .iter()
                    .map(ShapeEntry::from_json)
                    .collect()
            };
            artifacts.push(ArtifactMeta {
                file: a.get("file").and_then(|f| f.as_str()).unwrap_or("").to_string(),
                model: a.get("model").and_then(|f| f.as_str()).unwrap_or("").to_string(),
                recipe: a.get("recipe").and_then(|f| f.as_str()).unwrap_or("").to_string(),
                step: a.get("step").and_then(|f| f.as_str()).unwrap_or("").to_string(),
                use_pallas: a.get("use_pallas").and_then(|f| f.as_bool()).unwrap_or(false),
                inputs: shapes("inputs")?,
                outputs: shapes("outputs")?,
                sha256: a.get("sha256").and_then(|f| f.as_str()).unwrap_or("").to_string(),
            });
        }
        Ok(Manifest { batch, total_steps, models, recipes, table2_rows, artifacts })
    }

    pub fn find(&self, model: &str, recipe: &str, step: &str, use_pallas: bool) -> Option<&ArtifactMeta> {
        self.artifacts
            .iter()
            .find(|a| a.model == model && a.recipe == recipe && a.step == step && a.use_pallas == use_pallas)
    }

    pub fn model(&self, name: &str) -> Result<&ModelInfo> {
        self.models.get(name).ok_or_else(|| anyhow!("unknown model preset {name}"))
    }

    /// Number of flat parameter tensors of a model (state = 3n + 1).
    pub fn n_params(&self, model: &str) -> Result<usize> {
        Ok(self.model(model)?.params.len())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const SRC: &str = r#"{
      "version": 1, "batch": 8, "total_steps": 1200,
      "models": {"m": {"family": "gpt2", "vocab": 512, "layers": 4,
        "d_model": 128, "n_head": 4, "d_ff": 512, "seq": 256,
        "param_count": 1000,
        "params": [{"name": "a", "shape": [4, 128], "dtype": "float32"},
                   {"name": "b", "shape": [], "dtype": "float32"}]}},
      "recipes": {"ours": {"attn": {"fmt": "fp8", "granularity": "block", "block": 128},
                           "ffn": {"fmt": "fp4", "granularity": "block", "block": 128},
                           "wgrad": {"fmt": "fp8", "granularity": "block", "block": 128},
                           "agrad": {"fmt": "none", "granularity": "block", "block": 128}}},
      "table2_rows": ["ours"],
      "artifacts": [{"file": "m__ours__train.hlo.txt", "model": "m",
        "recipe": "ours", "step": "train", "use_pallas": false,
        "inputs": [{"shape": [4, 128], "dtype": "float32"}],
        "outputs": [{"shape": [], "dtype": "float32"}],
        "sha256": "x", "lower_seconds": 1.0}]
    }"#;

    #[test]
    fn parses_everything() {
        let m = Manifest::parse(SRC).unwrap();
        assert_eq!(m.batch, 8);
        assert_eq!(m.model("m").unwrap().params.len(), 2);
        assert_eq!(m.recipes["ours"].ffn, "fp4");
        assert_eq!(m.recipes["ours"].agrad, "none");
        let a = m.find("m", "ours", "train", false).unwrap();
        assert_eq!(a.inputs[0].shape, vec![4, 128]);
        assert_eq!(a.outputs[0].numel(), 1);
        assert!(m.find("m", "ours", "train", true).is_none());
    }

    #[test]
    fn real_manifest_loads_if_present() {
        let p = Path::new("artifacts/manifest.json");
        if p.exists() {
            let m = Manifest::load(p).unwrap();
            assert!(m.models.contains_key("gpt2-s-proxy"));
            assert!(m.find("gpt2-s-proxy", "ours", "train", false).is_some());
            // state inputs = 3n+1 (+1 batch)
            let n = m.n_params("gpt2-s-proxy").unwrap();
            let t = m.find("gpt2-s-proxy", "ours", "train", false).unwrap();
            assert_eq!(t.inputs.len(), 3 * n + 2);
            assert_eq!(t.outputs.len(), 3 * n + 3); // + loss + gnorm
        }
    }
}
