//! PJRT runtime: load the AOT HLO-text artifacts produced by
//! `python/compile/aot.py`, compile them on the CPU PJRT client, and run
//! training/eval steps with device-resident state.
//!
//! Key properties:
//! * HLO **text** interchange (xla_extension 0.5.1 rejects jax≥0.5 protos).
//! * The vendored `xla` crate is patched to set
//!   `ExecuteOptions::untuple_result`, so a step's tuple output arrives as
//!   one `PjRtBuffer` per element — outputs chain directly into the next
//!   `execute_b` call with zero host round-trips (L3 perf §Perf).
//!
//! When PJRT is unavailable (the compile-only `vendor/xla-stub` build, or
//! no artifacts directory), `Runtime::open` fails fast; the `--host` flag
//! routes training/reproduce through the pure-Rust `crate::refmodel`
//! engine instead, which needs neither.

pub mod manifest;
pub mod state;

use std::collections::HashMap;
use std::path::{Path, PathBuf};

use anyhow::{anyhow, Context, Result};

use crate::tensor::{Tensor, TensorI32};
use manifest::{ArtifactMeta, Manifest};

/// A PJRT client plus the artifact registry for one artifacts directory.
pub struct Runtime {
    pub client: xla::PjRtClient,
    pub manifest: Manifest,
    dir: PathBuf,
    cache: std::cell::RefCell<HashMap<String, std::rc::Rc<Executable>>>,
}

/// One compiled step function.
pub struct Executable {
    pub meta: ArtifactMeta,
    exe: xla::PjRtLoadedExecutable,
}

impl Runtime {
    /// Create a CPU PJRT client and read `manifest.json` from `dir`.
    pub fn open(dir: &Path) -> Result<Runtime> {
        let client = xla::PjRtClient::cpu().map_err(|e| anyhow!("pjrt: {e}"))?;
        let manifest = Manifest::load(&dir.join("manifest.json"))
            .with_context(|| format!("loading manifest from {}", dir.display()))?;
        Ok(Runtime { client, manifest, dir: dir.to_path_buf(), cache: Default::default() })
    }

    /// Load + compile an artifact by (model, recipe, step), memoized.
    pub fn load(&self, model: &str, recipe: &str, step: &str) -> Result<std::rc::Rc<Executable>> {
        self.load_variant(model, recipe, step, false)
    }

    pub fn load_variant(
        &self,
        model: &str,
        recipe: &str,
        step: &str,
        use_pallas: bool,
    ) -> Result<std::rc::Rc<Executable>> {
        let meta = self
            .manifest
            .find(model, recipe, step, use_pallas)
            .ok_or_else(|| {
                anyhow!("artifact not found: {model}/{recipe}/{step} (pallas={use_pallas}); re-run `make artifacts`")
            })?
            .clone();
        let key = meta.file.clone();
        if let Some(e) = self.cache.borrow().get(&key) {
            return Ok(e.clone());
        }
        let path = self.dir.join(&meta.file);
        let t0 = std::time::Instant::now();
        let proto = xla::HloModuleProto::from_text_file(&path)
            .map_err(|e| anyhow!("parse {}: {e}", path.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .map_err(|e| anyhow!("compile {}: {e}", path.display()))?;
        log::debug!("compiled {} in {:.2?}", meta.file, t0.elapsed());
        let rc = std::rc::Rc::new(Executable { meta, exe });
        self.cache.borrow_mut().insert(key, rc.clone());
        Ok(rc)
    }

    /// Upload a host f32 tensor.
    pub fn upload_f32(&self, t: &Tensor) -> Result<xla::PjRtBuffer> {
        let dims: Vec<usize> = t.shape.clone();
        self.client
            .buffer_from_host_buffer(&t.data, &dims, None)
            .map_err(|e| anyhow!("upload f32: {e}"))
    }

    /// Upload a host i32 tensor.
    pub fn upload_i32(&self, t: &TensorI32) -> Result<xla::PjRtBuffer> {
        self.client
            .buffer_from_host_buffer(&t.data, &t.shape, None)
            .map_err(|e| anyhow!("upload i32: {e}"))
    }

    pub fn upload_scalar_i32(&self, v: i32) -> Result<xla::PjRtBuffer> {
        self.client
            .buffer_from_host_buffer(&[v], &[], None)
            .map_err(|e| anyhow!("upload scalar: {e}"))
    }
}

/// Download a device buffer to a host f32 tensor.
pub fn download_f32(buf: &xla::PjRtBuffer) -> Result<Tensor> {
    let lit = buf.to_literal_sync().map_err(|e| anyhow!("download: {e}"))?;
    let shape = lit.array_shape().map_err(|e| anyhow!("shape: {e}"))?;
    let dims: Vec<usize> = shape.dims().iter().map(|&d| d as usize).collect();
    let data = lit.to_vec::<f32>().map_err(|e| anyhow!("to_vec f32: {e}"))?;
    Ok(Tensor::from_vec(&dims, data))
}

pub fn download_scalar_f32(buf: &xla::PjRtBuffer) -> Result<f32> {
    Ok(download_f32(buf)?.item())
}

pub fn download_i32(buf: &xla::PjRtBuffer) -> Result<TensorI32> {
    let lit = buf.to_literal_sync().map_err(|e| anyhow!("download: {e}"))?;
    let shape = lit.array_shape().map_err(|e| anyhow!("shape: {e}"))?;
    let dims: Vec<usize> = shape.dims().iter().map(|&d| d as usize).collect();
    let data = lit.to_vec::<i32>().map_err(|e| anyhow!("to_vec i32: {e}"))?;
    Ok(TensorI32::from_vec(&dims, data))
}

impl Executable {
    /// Execute with device buffers; returns one buffer per output.
    pub fn run(&self, args: &[&xla::PjRtBuffer]) -> Result<Vec<xla::PjRtBuffer>> {
        if args.len() != self.meta.inputs.len() {
            return Err(anyhow!(
                "{}: expected {} inputs, got {}",
                self.meta.file,
                self.meta.inputs.len(),
                args.len()
            ));
        }
        let mut outs = self
            .exe
            .execute_b(args)
            .map_err(|e| anyhow!("execute {}: {e}", self.meta.file))?;
        let replica0 = outs.swap_remove(0);
        if replica0.len() != self.meta.outputs.len() {
            return Err(anyhow!(
                "{}: manifest says {} outputs, runtime returned {} \
                 (is the vendored xla untuple patch active?)",
                self.meta.file,
                self.meta.outputs.len(),
                replica0.len()
            ));
        }
        Ok(replica0)
    }
}
