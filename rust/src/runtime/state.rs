//! Device-resident training state: the flat buffer list
//! `[params.. , m.. , v.. , step]` that train/apply steps consume and
//! produce.  Buffers never leave the device during the steady-state loop;
//! host copies happen only for init upload, checkpointing, and eval
//! scalars.

use anyhow::{anyhow, Result};
use xla::PjRtBuffer;

use super::{download_f32, download_i32, Executable, Runtime};
use crate::tensor::Tensor;

/// Flat device state. Layout: n params, n first moments, n second moments,
/// then the i32 step counter.
pub struct TrainState {
    pub bufs: Vec<PjRtBuffer>,
    pub n_params: usize,
}

impl TrainState {
    pub fn from_bufs(bufs: Vec<PjRtBuffer>, n_params: usize) -> Result<TrainState> {
        if bufs.len() != 3 * n_params + 1 {
            return Err(anyhow!(
                "state expects {} buffers, got {}",
                3 * n_params + 1,
                bufs.len()
            ));
        }
        Ok(TrainState { bufs, n_params })
    }

    /// Run the model's `init` executable (seeded) and wrap the result.
    pub fn init(rt: &Runtime, model: &str, recipe: &str, seed: i32) -> Result<TrainState> {
        let init = rt.load(model, recipe, "init")?;
        let seed_buf = rt.upload_scalar_i32(seed)?;
        let out = init.run(&[&seed_buf])?;
        let n = rt.manifest.n_params(model)?;
        TrainState::from_bufs(out, n)
    }

    pub fn params(&self) -> &[PjRtBuffer] {
        &self.bufs[..self.n_params]
    }

    pub fn param_refs(&self) -> Vec<&PjRtBuffer> {
        self.bufs[..self.n_params].iter().collect()
    }

    pub fn all_refs(&self) -> Vec<&PjRtBuffer> {
        self.bufs.iter().collect()
    }

    /// Current step counter (host round-trip; used at schedule boundaries).
    pub fn step(&self) -> Result<i64> {
        let t = download_i32(&self.bufs[3 * self.n_params])?;
        Ok(t.data[0] as i64)
    }

    /// Download all parameters (checkpointing).
    pub fn download_params(&self) -> Result<Vec<Tensor>> {
        self.params().iter().map(download_f32).collect()
    }

    /// Download the full optimizer state (params, m, v, step).
    pub fn download_all(&self) -> Result<(Vec<Tensor>, Vec<Tensor>, Vec<Tensor>, i64)> {
        let n = self.n_params;
        let p = self.bufs[..n].iter().map(download_f32).collect::<Result<Vec<_>>>()?;
        let m = self.bufs[n..2 * n].iter().map(download_f32).collect::<Result<Vec<_>>>()?;
        let v = self.bufs[2 * n..3 * n].iter().map(download_f32).collect::<Result<Vec<_>>>()?;
        let step = self.step()?;
        Ok((p, m, v, step))
    }

    /// Rebuild device state from host tensors (checkpoint restore).
    pub fn upload(
        rt: &Runtime,
        params: &[Tensor],
        m: &[Tensor],
        v: &[Tensor],
        step: i32,
    ) -> Result<TrainState> {
        let n = params.len();
        if m.len() != n || v.len() != n {
            return Err(anyhow!("moment count mismatch"));
        }
        let mut bufs = Vec::with_capacity(3 * n + 1);
        for t in params.iter().chain(m).chain(v) {
            bufs.push(rt.upload_f32(t)?);
        }
        bufs.push(rt.upload_scalar_i32(step)?);
        TrainState::from_bufs(bufs, n)
    }

    /// One fused train step: consumes self, returns (new state, loss, gnorm).
    pub fn train_step(
        self,
        exe: &Executable,
        batch: &PjRtBuffer,
    ) -> Result<(TrainState, f32, f32)> {
        let mut args: Vec<&PjRtBuffer> = self.bufs.iter().collect();
        args.push(batch);
        let mut out = exe.run(&args)?;
        let gnorm_buf = out.pop().ok_or_else(|| anyhow!("missing gnorm output"))?;
        let loss_buf = out.pop().ok_or_else(|| anyhow!("missing loss output"))?;
        let loss = super::download_scalar_f32(&loss_buf)?;
        let gnorm = super::download_scalar_f32(&gnorm_buf)?;
        let st = TrainState::from_bufs(out, self.n_params)?;
        Ok((st, loss, gnorm))
    }

    /// Apply externally averaged gradients (data-parallel path):
    /// state ++ grads -> state' ++ [gnorm].
    pub fn apply_step(
        self,
        exe: &Executable,
        grads: &[PjRtBuffer],
    ) -> Result<(TrainState, f32)> {
        let mut args: Vec<&PjRtBuffer> = self.bufs.iter().collect();
        args.extend(grads.iter());
        let mut out = exe.run(&args)?;
        let gnorm_buf = out.pop().ok_or_else(|| anyhow!("missing gnorm output"))?;
        let gnorm = super::download_scalar_f32(&gnorm_buf)?;
        let st = TrainState::from_bufs(out, self.n_params)?;
        Ok((st, gnorm))
    }
}

/// Evaluate mean NLL over validation batches (full-precision forward).
pub fn eval_nll(
    rt: &Runtime,
    exe: &Executable,
    state: &TrainState,
    batches: &[crate::tensor::TensorI32],
) -> Result<f64> {
    let mut total = 0.0f64;
    let mut count = 0.0f64;
    for b in batches {
        let bb = rt.upload_i32(b)?;
        let mut args = state.param_refs();
        args.push(&bb);
        let out = exe.run(&args)?;
        total += super::download_scalar_f32(&out[0])? as f64;
        count += super::download_scalar_f32(&out[1])? as f64;
    }
    if count == 0.0 {
        return Err(anyhow!("no eval batches"));
    }
    Ok(total / count)
}
