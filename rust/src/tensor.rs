//! Minimal host tensor: row-major f32/i32 arrays with shape, used at the
//! rust↔PJRT boundary and by the analysis/eval layers.  Deliberately tiny —
//! heavy math lives in the AOT-compiled XLA executables; host-side code
//! only needs construction, indexing, simple reductions, and the small
//! dense ops the probe trainer uses.

use crate::util::rng::Rng;

#[derive(Clone, Debug, PartialEq)]
pub struct Tensor {
    pub shape: Vec<usize>,
    pub data: Vec<f32>,
}

#[derive(Clone, Debug, PartialEq)]
pub struct TensorI32 {
    pub shape: Vec<usize>,
    pub data: Vec<i32>,
}

pub fn numel(shape: &[usize]) -> usize {
    shape.iter().product()
}

impl Tensor {
    pub fn zeros(shape: &[usize]) -> Self {
        Tensor { shape: shape.to_vec(), data: vec![0.0; numel(shape)] }
    }

    pub fn from_vec(shape: &[usize], data: Vec<f32>) -> Self {
        assert_eq!(numel(shape), data.len(), "shape {shape:?} vs len {}", data.len());
        Tensor { shape: shape.to_vec(), data }
    }

    pub fn scalar(v: f32) -> Self {
        Tensor { shape: vec![], data: vec![v] }
    }

    pub fn randn(shape: &[usize], std: f32, rng: &mut Rng) -> Self {
        let data = (0..numel(shape)).map(|_| rng.normal_f32(0.0, std)).collect();
        Tensor { shape: shape.to_vec(), data }
    }

    pub fn numel(&self) -> usize {
        self.data.len()
    }

    pub fn rank(&self) -> usize {
        self.shape.len()
    }

    /// Row-major strides.
    pub fn strides(&self) -> Vec<usize> {
        let mut s = vec![1; self.shape.len()];
        for i in (0..self.shape.len().saturating_sub(1)).rev() {
            s[i] = s[i + 1] * self.shape[i + 1];
        }
        s
    }

    pub fn at(&self, idx: &[usize]) -> f32 {
        debug_assert_eq!(idx.len(), self.shape.len());
        let s = self.strides();
        let off: usize = idx.iter().zip(&s).map(|(i, st)| i * st).sum();
        self.data[off]
    }

    pub fn reshape(mut self, shape: &[usize]) -> Self {
        assert_eq!(numel(shape), self.data.len());
        self.shape = shape.to_vec();
        self
    }

    pub fn item(&self) -> f32 {
        assert_eq!(self.data.len(), 1, "item() on non-scalar {:?}", self.shape);
        self.data[0]
    }

    pub fn mean(&self) -> f32 {
        if self.data.is_empty() {
            return f32::NAN;
        }
        self.data.iter().sum::<f32>() / self.data.len() as f32
    }

    pub fn abs_max(&self) -> f32 {
        self.data.iter().fold(0.0f32, |a, &x| a.max(x.abs()))
    }

    pub fn l2(&self) -> f32 {
        self.data.iter().map(|&x| x * x).sum::<f32>().sqrt()
    }

    /// (rows, cols) matmul for host-side math (the probe trainer).
    /// Cache-blocked and thread-parallel for large problems via
    /// `kernels::matmul_f32`; accumulation order matches the naive loop,
    /// so results are bit-identical at every size.
    pub fn matmul(&self, other: &Tensor) -> Tensor {
        assert_eq!(self.rank(), 2);
        assert_eq!(other.rank(), 2);
        let (m, k) = (self.shape[0], self.shape[1]);
        let (k2, n) = (other.shape[0], other.shape[1]);
        assert_eq!(k, k2);
        Tensor::from_vec(&[m, n], crate::kernels::matmul_f32(&self.data, &other.data, m, k, n))
    }

    /// `self @ q` where the right operand stays in packed quantized form —
    /// `kernels::qgemm` decodes B panel-by-panel, so no f32 copy of B is
    /// ever materialized.  Bit-identical to
    /// `self.matmul(&quant::dequantize(q))`.
    ///
    /// Callers that multiply against the same `q` repeatedly should pass
    /// a cache-enabled workspace (`Workspace::with_panel_cache`): decoded
    /// panels are then reused across calls instead of re-decoded, with
    /// identical bits either way.
    pub fn matmul_quant(
        &self,
        q: &crate::quant::QuantizedTensor,
        ws: &mut crate::kernels::Workspace,
    ) -> Tensor {
        assert_eq!(self.rank(), 2);
        let (m, k) = (self.shape[0], self.shape[1]);
        let (k2, n) = q.rows_cols();
        assert_eq!(k, k2, "A cols {k} vs B rows {k2}");
        let mut out = vec![0.0f32; m * n];
        crate::kernels::qgemm_into(&self.data, q, m, k, n, &mut out, ws);
        Tensor::from_vec(&[m, n], out)
    }

    /// `self @ qᵀ` where the right operand is **stored** `(n, k)` packed
    /// — `kernels::qgemm_bt` decodes transposed panels in place, so
    /// neither the f32 matrix nor its transpose is materialized.
    /// Bit-identical to
    /// `self.matmul(&quant::dequantize(q).transpose2())`.  Same workspace
    /// / panel-cache guidance as [`Tensor::matmul_quant`]; cached panels
    /// are keyed by orientation, so one tensor may be multiplied both
    /// ways through one workspace.
    pub fn matmul_quant_bt(
        &self,
        q: &crate::quant::QuantizedTensor,
        ws: &mut crate::kernels::Workspace,
    ) -> Tensor {
        assert_eq!(self.rank(), 2);
        let (m, k) = (self.shape[0], self.shape[1]);
        let (n, k2) = q.rows_cols();
        assert_eq!(k, k2, "A cols {k} vs stored B cols {k2}");
        let mut out = vec![0.0f32; m * n];
        crate::kernels::qgemm_bt_into(&self.data, q, m, k, n, &mut out, ws);
        Tensor::from_vec(&[m, n], out)
    }

    /// Row-major transpose (used to feed gradient matmuls).
    pub fn transpose2(&self) -> Tensor {
        assert_eq!(self.rank(), 2);
        let (m, n) = (self.shape[0], self.shape[1]);
        let mut out = vec![0.0f32; m * n];
        transpose_into(&self.data, m, n, &mut out);
        Tensor::from_vec(&[n, m], out)
    }
}

/// Transpose a row-major (rows × cols) buffer into `out` (cols × rows),
/// resizing `out` as needed.  The zero-steady-state-allocation form the
/// refmodel backward uses for its gradient-GEMM operands.
pub fn transpose_into(src: &[f32], rows: usize, cols: usize, out: &mut Vec<f32>) {
    assert_eq!(src.len(), rows * cols);
    out.resize(rows * cols, 0.0);
    for i in 0..rows {
        for j in 0..cols {
            out[j * rows + i] = src[i * cols + j];
        }
    }
}

impl TensorI32 {
    pub fn from_vec(shape: &[usize], data: Vec<i32>) -> Self {
        assert_eq!(numel(shape), data.len());
        TensorI32 { shape: shape.to_vec(), data }
    }

    pub fn zeros(shape: &[usize]) -> Self {
        TensorI32 { shape: shape.to_vec(), data: vec![0; numel(shape)] }
    }

    pub fn numel(&self) -> usize {
        self.data.len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn strides_and_at() {
        let t = Tensor::from_vec(&[2, 3], vec![0., 1., 2., 3., 4., 5.]);
        assert_eq!(t.strides(), vec![3, 1]);
        assert_eq!(t.at(&[1, 2]), 5.0);
        assert_eq!(t.at(&[0, 1]), 1.0);
    }

    #[test]
    fn matmul_matches_manual() {
        let a = Tensor::from_vec(&[2, 2], vec![1., 2., 3., 4.]);
        let b = Tensor::from_vec(&[2, 2], vec![5., 6., 7., 8.]);
        assert_eq!(a.matmul(&b).data, vec![19., 22., 43., 50.]);
    }

    #[test]
    fn transpose2_roundtrip() {
        let a = Tensor::from_vec(&[2, 3], vec![0., 1., 2., 3., 4., 5.]);
        let t = a.transpose2();
        assert_eq!(t.shape, vec![3, 2]);
        assert_eq!(t.data, vec![0., 3., 1., 4., 2., 5.]);
        assert_eq!(t.transpose2(), a);
    }

    #[test]
    fn transpose_into_resizes_and_reuses() {
        let src = vec![1.0f32, 2., 3., 4., 5., 6.];
        let mut out = vec![f32::NAN; 2]; // wrong size + dirty: must be fixed up
        transpose_into(&src, 2, 3, &mut out);
        assert_eq!(out, vec![1., 4., 2., 5., 3., 6.]);
        let mut back = Vec::new();
        transpose_into(&out, 3, 2, &mut back);
        assert_eq!(back, src);
    }

    #[test]
    fn reductions() {
        let t = Tensor::from_vec(&[4], vec![1., -3., 2., 0.]);
        assert_eq!(t.mean(), 0.0);
        assert_eq!(t.abs_max(), 3.0);
        assert!((t.l2() - 14.0f32.sqrt()).abs() < 1e-6);
    }

    #[test]
    #[should_panic]
    fn shape_mismatch_panics() {
        Tensor::from_vec(&[2, 2], vec![1.0]);
    }

    #[test]
    fn matmul_quant_matches_dequantized_matmul() {
        use crate::formats::FP4_E2M1;
        use crate::quant::{dequantize, quantize, GranSpec};
        let mut rng = Rng::new(3);
        let a = Tensor::randn(&[4, 32], 1.0, &mut rng);
        let b = Tensor::randn(&[32, 8], 1.0, &mut rng);
        let q = quantize(&b, FP4_E2M1, GranSpec::PerRow);
        let mut ws = crate::kernels::Workspace::new();
        assert_eq!(a.matmul_quant(&q, &mut ws), a.matmul(&dequantize(&q)));
        // cache-enabled workspace: same bits on the miss and the hit pass
        let mut cws = crate::kernels::Workspace::with_panel_cache(1 << 20);
        let want = a.matmul(&dequantize(&q));
        assert_eq!(a.matmul_quant(&q, &mut cws), want);
        assert_eq!(a.matmul_quant(&q, &mut cws), want);
        let stats = cws.panel_cache_stats().unwrap();
        assert!(stats.hits > 0 && stats.misses > 0, "{stats:?}");
    }

    #[test]
    fn matmul_quant_bt_matches_transposed_dequantized_matmul() {
        use crate::formats::FP4_E2M1;
        use crate::quant::{dequantize, quantize, GranSpec};
        fn bits(t: &Tensor) -> Vec<u32> {
            t.data.iter().map(|v| v.to_bits()).collect()
        }
        let mut rng = Rng::new(4);
        let a = Tensor::randn(&[4, 32], 1.0, &mut rng);
        let b = Tensor::randn(&[8, 32], 1.0, &mut rng); // stored (n, k), K-grouped
        let q = quantize(&b, FP4_E2M1, GranSpec::PerBlock(8));
        let want = a.matmul(&dequantize(&q).transpose2());
        let mut ws = crate::kernels::Workspace::new();
        assert_eq!(bits(&a.matmul_quant_bt(&q, &mut ws)), bits(&want));
        // one cached workspace serving both orientations of the same q
        let mut cws = crate::kernels::Workspace::with_panel_cache(1 << 20);
        assert_eq!(bits(&a.matmul_quant_bt(&q, &mut cws)), bits(&want));
        let g = Tensor::randn(&[4, 8], 1.0, &mut rng);
        let want_dx = g.matmul(&dequantize(&q));
        assert_eq!(bits(&g.matmul_quant(&q, &mut cws)), bits(&want_dx));
        assert_eq!(bits(&a.matmul_quant_bt(&q, &mut cws)), bits(&want));
        assert_eq!(bits(&g.matmul_quant(&q, &mut cws)), bits(&want_dx));
        let stats = cws.panel_cache_stats().unwrap();
        assert!(stats.hits > 0 && stats.misses > 0, "{stats:?}");
    }

    #[test]
    fn randn_deterministic() {
        let mut r1 = Rng::new(1);
        let mut r2 = Rng::new(1);
        assert_eq!(Tensor::randn(&[8], 1.0, &mut r1), Tensor::randn(&[8], 1.0, &mut r2));
    }
}
