//! Tiny CLI argument parser: `prog <subcommand> [--key value] [--flag]
//! [positional...]`.  Declarative option registry gives automatic `--help`.

use std::collections::BTreeMap;

#[derive(Debug, Clone)]
pub struct ArgSpec {
    pub name: &'static str,
    pub help: &'static str,
    pub default: Option<&'static str>,
    pub is_flag: bool,
}

#[derive(Debug, Default)]
pub struct Args {
    pub subcommand: Option<String>,
    values: BTreeMap<String, String>,
    flags: Vec<String>,
    pub positional: Vec<String>,
}

#[derive(Debug)]
pub struct ArgError(pub String);

impl std::fmt::Display for ArgError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "{}", self.0)
    }
}
impl std::error::Error for ArgError {}

pub struct Cli {
    pub name: &'static str,
    pub about: &'static str,
    pub specs: Vec<ArgSpec>,
    pub subcommands: Vec<(&'static str, &'static str)>,
}

impl Cli {
    pub fn new(name: &'static str, about: &'static str) -> Self {
        Cli { name, about, specs: Vec::new(), subcommands: Vec::new() }
    }

    pub fn opt(mut self, name: &'static str, default: Option<&'static str>, help: &'static str) -> Self {
        self.specs.push(ArgSpec { name, help, default, is_flag: false });
        self
    }

    pub fn flag(mut self, name: &'static str, help: &'static str) -> Self {
        self.specs.push(ArgSpec { name, help, default: None, is_flag: true });
        self
    }

    pub fn sub(mut self, name: &'static str, help: &'static str) -> Self {
        self.subcommands.push((name, help));
        self
    }

    pub fn help_text(&self) -> String {
        let mut s = format!("{} — {}\n\nUSAGE:\n  {} [subcommand] [options]\n", self.name, self.about, self.name);
        if !self.subcommands.is_empty() {
            s.push_str("\nSUBCOMMANDS:\n");
            for (n, h) in &self.subcommands {
                s.push_str(&format!("  {n:<18} {h}\n"));
            }
        }
        s.push_str("\nOPTIONS:\n");
        for spec in &self.specs {
            let d = spec.default.map(|d| format!(" [default: {d}]")).unwrap_or_default();
            let kind = if spec.is_flag { "" } else { " <value>" };
            s.push_str(&format!("  --{}{kind:<10} {}{d}\n", spec.name, spec.help));
        }
        s.push_str("  --help             print this help\n");
        s
    }

    /// Parse argv (without the program name).
    pub fn parse(&self, argv: &[String]) -> Result<Args, ArgError> {
        let mut out = Args::default();
        for spec in &self.specs {
            if let Some(d) = spec.default {
                out.values.insert(spec.name.to_string(), d.to_string());
            }
        }
        let mut it = argv.iter().peekable();
        // optional subcommand first
        if let Some(first) = it.peek() {
            if !first.starts_with('-') && self.subcommands.iter().any(|(n, _)| *n == first.as_str()) {
                out.subcommand = Some(it.next().unwrap().clone());
            }
        }
        while let Some(a) = it.next() {
            if a == "--help" || a == "-h" {
                return Err(ArgError(self.help_text()));
            }
            if let Some(name) = a.strip_prefix("--") {
                let (key, inline) = match name.split_once('=') {
                    Some((k, v)) => (k, Some(v.to_string())),
                    None => (name, None),
                };
                let spec = self
                    .specs
                    .iter()
                    .find(|s| s.name == key)
                    .ok_or_else(|| ArgError(format!("unknown option --{key}\n\n{}", self.help_text())))?;
                if spec.is_flag {
                    if inline.is_some() {
                        return Err(ArgError(format!("--{key} is a flag")));
                    }
                    out.flags.push(key.to_string());
                } else {
                    let v = match inline {
                        Some(v) => v,
                        None => it
                            .next()
                            .ok_or_else(|| ArgError(format!("--{key} needs a value")))?
                            .clone(),
                    };
                    out.values.insert(key.to_string(), v);
                }
            } else {
                out.positional.push(a.clone());
            }
        }
        Ok(out)
    }
}

impl Args {
    pub fn get(&self, key: &str) -> Option<&str> {
        self.values.get(key).map(|s| s.as_str())
    }

    pub fn req(&self, key: &str) -> Result<&str, ArgError> {
        self.get(key).ok_or_else(|| ArgError(format!("missing --{key}")))
    }

    pub fn get_parsed<T: std::str::FromStr>(&self, key: &str) -> Result<Option<T>, ArgError> {
        match self.get(key) {
            None => Ok(None),
            Some(s) => s
                .parse::<T>()
                .map(Some)
                .map_err(|_| ArgError(format!("bad value for --{key}: {s}"))),
        }
    }

    pub fn usize_or(&self, key: &str, default: usize) -> Result<usize, ArgError> {
        Ok(self.get_parsed::<usize>(key)?.unwrap_or(default))
    }

    pub fn f64_or(&self, key: &str, default: f64) -> Result<f64, ArgError> {
        Ok(self.get_parsed::<f64>(key)?.unwrap_or(default))
    }

    pub fn has_flag(&self, key: &str) -> bool {
        self.flags.iter().any(|f| f == key)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cli() -> Cli {
        Cli::new("t", "test")
            .sub("train", "train a model")
            .sub("eval", "evaluate")
            .opt("steps", Some("100"), "number of steps")
            .opt("model", None, "model preset")
            .flag("verbose", "noisy output")
    }

    fn argv(xs: &[&str]) -> Vec<String> {
        xs.iter().map(|s| s.to_string()).collect()
    }

    #[test]
    fn parses_subcommand_options_flags() {
        let a = cli().parse(&argv(&["train", "--steps", "5", "--verbose", "extra"])).unwrap();
        assert_eq!(a.subcommand.as_deref(), Some("train"));
        assert_eq!(a.usize_or("steps", 0).unwrap(), 5);
        assert!(a.has_flag("verbose"));
        assert_eq!(a.positional, vec!["extra"]);
    }

    #[test]
    fn equals_syntax_and_defaults() {
        let a = cli().parse(&argv(&["--model=gpt2"])).unwrap();
        assert_eq!(a.get("model"), Some("gpt2"));
        assert_eq!(a.usize_or("steps", 0).unwrap(), 100); // default
    }

    #[test]
    fn unknown_option_rejected() {
        assert!(cli().parse(&argv(&["--nope"])).is_err());
    }

    #[test]
    fn missing_value_rejected() {
        assert!(cli().parse(&argv(&["--model"])).is_err());
    }

    #[test]
    fn bad_parse_rejected() {
        let a = cli().parse(&argv(&["--steps", "abc"])).unwrap();
        assert!(a.usize_or("steps", 0).is_err());
    }

    #[test]
    fn help_lists_everything() {
        let h = cli().help_text();
        assert!(h.contains("--steps") && h.contains("train") && h.contains("default: 100"));
    }
}
