//! Minimal JSON: a recursive-descent parser and a writer.
//!
//! Covers the full JSON grammar (objects, arrays, strings with escapes,
//! numbers, bools, null).  Object key order is preserved (insertion order)
//! so manifests round-trip stably.  No serde in the offline registry — see
//! util/mod.rs.

use std::collections::BTreeMap;
use std::fmt;

#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(Vec<(String, Json)>),
}

#[derive(Debug)]
pub struct JsonError {
    pub msg: String,
    pub pos: usize,
}

impl fmt::Display for JsonError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "json error at byte {}: {}", self.pos, self.msg)
    }
}

impl std::error::Error for JsonError {}

impl Json {
    pub fn parse(s: &str) -> Result<Json, JsonError> {
        let mut p = Parser { b: s.as_bytes(), i: 0 };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.i != p.b.len() {
            return Err(p.err("trailing data"));
        }
        Ok(v)
    }

    // -- typed accessors ---------------------------------------------------

    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(kvs) => kvs.iter().find(|(k, _)| k == key).map(|(_, v)| v),
            _ => None,
        }
    }

    pub fn idx(&self, i: usize) -> Option<&Json> {
        match self {
            Json::Arr(xs) => xs.get(i),
            _ => None,
        }
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_i64(&self) -> Option<i64> {
        self.as_f64().map(|n| n as i64)
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|n| n as usize)
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(xs) => Some(xs),
            _ => None,
        }
    }

    pub fn members(&self) -> Option<&[(String, Json)]> {
        match self {
            Json::Obj(kvs) => Some(kvs),
            _ => None,
        }
    }

    /// Path accessor: `j.at(&["models", "gpt2-s-proxy", "seq"])`.
    pub fn at(&self, path: &[&str]) -> Option<&Json> {
        let mut cur = self;
        for k in path {
            cur = cur.get(k)?;
        }
        Some(cur)
    }

    // -- writer --------------------------------------------------------------

    pub fn to_string_pretty(&self) -> String {
        let mut out = String::new();
        self.write(&mut out, Some(0));
        out
    }

    pub fn to_string_compact(&self) -> String {
        let mut out = String::new();
        self.write(&mut out, None);
        out
    }

    fn write(&self, out: &mut String, indent: Option<usize>) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(n) => {
                if n.fract() == 0.0 && n.abs() < 1e15 {
                    out.push_str(&format!("{}", *n as i64));
                } else {
                    out.push_str(&format!("{n}"));
                }
            }
            Json::Str(s) => write_escaped(out, s),
            Json::Arr(xs) => {
                out.push('[');
                for (i, x) in xs.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    if let Some(d) = indent {
                        newline(out, d + 1);
                        x.write(out, Some(d + 1));
                    } else {
                        x.write(out, None);
                    }
                }
                if let Some(d) = indent {
                    if !xs.is_empty() {
                        newline(out, d);
                    }
                }
                out.push(']');
            }
            Json::Obj(kvs) => {
                out.push('{');
                for (i, (k, v)) in kvs.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    if let Some(d) = indent {
                        newline(out, d + 1);
                    }
                    write_escaped(out, k);
                    out.push(':');
                    if indent.is_some() {
                        out.push(' ');
                    }
                    v.write(out, indent.map(|d| d + 1));
                }
                if let Some(d) = indent {
                    if !kvs.is_empty() {
                        newline(out, d);
                    }
                }
                out.push('}');
            }
        }
    }
}

fn newline(out: &mut String, depth: usize) {
    out.push('\n');
    for _ in 0..depth {
        out.push_str(" ");
    }
}

fn write_escaped(out: &mut String, s: &str) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out.push('"');
}

/// Convenience builder for object literals.
pub fn obj(kvs: Vec<(&str, Json)>) -> Json {
    Json::Obj(kvs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
}

impl From<f64> for Json {
    fn from(n: f64) -> Self {
        Json::Num(n)
    }
}
impl From<usize> for Json {
    fn from(n: usize) -> Self {
        Json::Num(n as f64)
    }
}
impl From<i64> for Json {
    fn from(n: i64) -> Self {
        Json::Num(n as f64)
    }
}
impl From<&str> for Json {
    fn from(s: &str) -> Self {
        Json::Str(s.to_string())
    }
}
impl From<String> for Json {
    fn from(s: String) -> Self {
        Json::Str(s)
    }
}
impl From<bool> for Json {
    fn from(b: bool) -> Self {
        Json::Bool(b)
    }
}
impl<T: Into<Json>> From<Vec<T>> for Json {
    fn from(xs: Vec<T>) -> Self {
        Json::Arr(xs.into_iter().map(Into::into).collect())
    }
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> JsonError {
        JsonError { msg: msg.to_string(), pos: self.i }
    }

    fn skip_ws(&mut self) {
        while self.i < self.b.len() && matches!(self.b[self.i], b' ' | b'\t' | b'\n' | b'\r') {
            self.i += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.i).copied()
    }

    fn eat(&mut self, c: u8) -> Result<(), JsonError> {
        if self.peek() == Some(c) {
            self.i += 1;
            Ok(())
        } else {
            Err(self.err(&format!("expected {:?}", c as char)))
        }
    }

    fn value(&mut self) -> Result<Json, JsonError> {
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'n') => self.lit("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(self.err("unexpected character")),
        }
    }

    fn lit(&mut self, word: &str, v: Json) -> Result<Json, JsonError> {
        if self.b[self.i..].starts_with(word.as_bytes()) {
            self.i += word.len();
            Ok(v)
        } else {
            Err(self.err(&format!("expected `{word}`")))
        }
    }

    fn number(&mut self) -> Result<Json, JsonError> {
        let start = self.i;
        if self.peek() == Some(b'-') {
            self.i += 1;
        }
        while self.peek().is_some_and(|c| c.is_ascii_digit()) {
            self.i += 1;
        }
        if self.peek() == Some(b'.') {
            self.i += 1;
            while self.peek().is_some_and(|c| c.is_ascii_digit()) {
                self.i += 1;
            }
        }
        if matches!(self.peek(), Some(b'e') | Some(b'E')) {
            self.i += 1;
            if matches!(self.peek(), Some(b'+') | Some(b'-')) {
                self.i += 1;
            }
            while self.peek().is_some_and(|c| c.is_ascii_digit()) {
                self.i += 1;
            }
        }
        let s = std::str::from_utf8(&self.b[start..self.i]).unwrap();
        s.parse::<f64>()
            .map(Json::Num)
            .map_err(|_| self.err("bad number"))
    }

    fn string(&mut self) -> Result<String, JsonError> {
        self.eat(b'"')?;
        let mut out = String::new();
        loop {
            match self.peek() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => {
                    self.i += 1;
                    return Ok(out);
                }
                Some(b'\\') => {
                    self.i += 1;
                    match self.peek() {
                        Some(b'"') => out.push('"'),
                        Some(b'\\') => out.push('\\'),
                        Some(b'/') => out.push('/'),
                        Some(b'b') => out.push('\u{8}'),
                        Some(b'f') => out.push('\u{c}'),
                        Some(b'n') => out.push('\n'),
                        Some(b'r') => out.push('\r'),
                        Some(b't') => out.push('\t'),
                        Some(b'u') => {
                            if self.i + 4 >= self.b.len() {
                                return Err(self.err("bad \\u escape"));
                            }
                            let hex = std::str::from_utf8(&self.b[self.i + 1..self.i + 5])
                                .map_err(|_| self.err("bad \\u escape"))?;
                            let cp = u32::from_str_radix(hex, 16)
                                .map_err(|_| self.err("bad \\u escape"))?;
                            // (surrogate pairs unsupported; manifests are ASCII)
                            out.push(char::from_u32(cp).unwrap_or('\u{fffd}'));
                            self.i += 4;
                        }
                        _ => return Err(self.err("bad escape")),
                    }
                    self.i += 1;
                }
                Some(_) => {
                    // advance one UTF-8 char
                    let s = std::str::from_utf8(&self.b[self.i..])
                        .map_err(|_| self.err("invalid utf8"))?;
                    let c = s.chars().next().unwrap();
                    out.push(c);
                    self.i += c.len_utf8();
                }
            }
        }
    }

    fn array(&mut self) -> Result<Json, JsonError> {
        self.eat(b'[')?;
        let mut xs = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.i += 1;
            return Ok(Json::Arr(xs));
        }
        loop {
            self.skip_ws();
            xs.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b']') => {
                    self.i += 1;
                    return Ok(Json::Arr(xs));
                }
                _ => return Err(self.err("expected , or ]")),
            }
        }
    }

    fn object(&mut self) -> Result<Json, JsonError> {
        self.eat(b'{')?;
        let mut kvs: Vec<(String, Json)> = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.i += 1;
            return Ok(Json::Obj(kvs));
        }
        loop {
            self.skip_ws();
            let k = self.string()?;
            self.skip_ws();
            self.eat(b':')?;
            self.skip_ws();
            let v = self.value()?;
            kvs.push((k, v));
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b'}') => {
                    self.i += 1;
                    return Ok(Json::Obj(kvs));
                }
                _ => return Err(self.err("expected , or }")),
            }
        }
    }
}

/// Map-style view for tests / callers wanting sorted access.
pub fn to_map(j: &Json) -> Option<BTreeMap<String, Json>> {
    j.members()
        .map(|kvs| kvs.iter().map(|(k, v)| (k.clone(), v.clone())).collect())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_scalars() {
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
        assert_eq!(Json::parse("true").unwrap(), Json::Bool(true));
        assert_eq!(Json::parse("-1.5e3").unwrap(), Json::Num(-1500.0));
        assert_eq!(Json::parse(r#""a\nb""#).unwrap(), Json::Str("a\nb".into()));
    }

    #[test]
    fn parse_nested() {
        let j = Json::parse(r#"{"a": [1, 2, {"b": "c"}], "d": {"e": null}}"#).unwrap();
        assert_eq!(j.at(&["a"]).unwrap().idx(2).unwrap().get("b").unwrap().as_str(), Some("c"));
        assert_eq!(j.at(&["d", "e"]), Some(&Json::Null));
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("1 2").is_err());
        assert!(Json::parse(r#"{"a" 1}"#).is_err());
    }

    #[test]
    fn roundtrip_pretty_and_compact() {
        let src = r#"{"models":{"m":{"shape":[2,3],"ok":true}},"x":1.25,"s":"q\"uote"}"#;
        let j = Json::parse(src).unwrap();
        for s in [j.to_string_pretty(), j.to_string_compact()] {
            assert_eq!(Json::parse(&s).unwrap(), j);
        }
    }

    #[test]
    fn preserves_key_order() {
        let j = Json::parse(r#"{"z":1,"a":2,"m":3}"#).unwrap();
        let keys: Vec<_> = j.members().unwrap().iter().map(|(k, _)| k.as_str()).collect();
        assert_eq!(keys, ["z", "a", "m"]);
    }

    #[test]
    fn unicode_and_escapes() {
        let j = Json::parse(r#""héllo A""#).unwrap();
        assert_eq!(j.as_str(), Some("héllo A"));
    }

    #[test]
    fn integer_formatting() {
        assert_eq!(Json::Num(3.0).to_string_compact(), "3");
        assert_eq!(Json::Num(3.5).to_string_compact(), "3.5");
    }
}
