//! Minimal `log` facade backend: timestamped stderr lines with a level
//! filter from `FP4TRAIN_LOG` (error|warn|info|debug|trace).

use log::{Level, LevelFilter, Metadata, Record};
use std::time::{SystemTime, UNIX_EPOCH};

struct StderrLogger;

static LOGGER: StderrLogger = StderrLogger;

impl log::Log for StderrLogger {
    fn enabled(&self, _m: &Metadata) -> bool {
        true
    }

    fn log(&self, record: &Record) {
        if self.enabled(record.metadata()) {
            let t = SystemTime::now().duration_since(UNIX_EPOCH).unwrap_or_default();
            let secs = t.as_secs();
            let (h, m, s) = ((secs / 3600) % 24, (secs / 60) % 60, secs % 60);
            let lvl = match record.level() {
                Level::Error => "ERROR",
                Level::Warn => "WARN ",
                Level::Info => "INFO ",
                Level::Debug => "DEBUG",
                Level::Trace => "TRACE",
            };
            eprintln!("[{h:02}:{m:02}:{s:02}.{:03} {lvl} {}] {}", t.subsec_millis(), record.target(), record.args());
        }
    }

    fn flush(&self) {}
}

/// Install the logger once; level from `FP4TRAIN_LOG` (default info).
pub fn init() {
    let level = match std::env::var("FP4TRAIN_LOG").as_deref() {
        Ok("error") => LevelFilter::Error,
        Ok("warn") => LevelFilter::Warn,
        Ok("debug") => LevelFilter::Debug,
        Ok("trace") => LevelFilter::Trace,
        _ => LevelFilter::Info,
    };
    let _ = log::set_logger(&LOGGER).map(|_| log::set_max_level(level));
}

#[cfg(test)]
mod tests {
    #[test]
    fn init_is_idempotent() {
        super::init();
        super::init();
        log::info!("logger smoke");
    }
}
