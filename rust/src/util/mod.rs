//! From-scratch utility substrates.
//!
//! This build environment is fully offline with only the `xla` crate's
//! dependency closure available, so the usual ecosystem crates (serde,
//! clap, rand, criterion...) are reimplemented here at the scale this
//! project needs.  Each module is self-contained and unit/property tested.

pub mod args;
pub mod json;
pub mod logger;
pub mod proptest;
pub mod rng;
pub mod stats;
pub mod tomlmini;

pub use rng::Rng;

/// FNV-1a 64-bit hash — the repo's one content digest, used for the
/// checkpoint payload checksum and the run store's config hash.  Not
/// cryptographic; it only needs to catch truncation, bit rot, and
/// accidental config drift.  Serialize the result as `{:016x}` hex:
/// `util::json` numbers are f64 and cannot hold a u64 exactly.
pub fn fnv1a64(bytes: &[u8]) -> u64 {
    let mut h: u64 = 0xcbf2_9ce4_8422_2325;
    for &b in bytes {
        h ^= b as u64;
        h = h.wrapping_mul(0x0000_0100_0000_01b3);
    }
    h
}

#[cfg(test)]
mod fnv_tests {
    use super::fnv1a64;

    #[test]
    fn known_vectors() {
        // standard FNV-1a test vectors
        assert_eq!(fnv1a64(b""), 0xcbf29ce484222325);
        assert_eq!(fnv1a64(b"a"), 0xaf63dc4c8601ec8c);
        assert_eq!(fnv1a64(b"foobar"), 0x85944171f73967e8);
    }

    #[test]
    fn sensitive_to_single_bit() {
        let a = fnv1a64(b"checkpoint payload");
        let b = fnv1a64(b"checkpoint pazload");
        assert_ne!(a, b);
    }
}
