//! From-scratch utility substrates.
//!
//! This build environment is fully offline with only the `xla` crate's
//! dependency closure available, so the usual ecosystem crates (serde,
//! clap, rand, criterion...) are reimplemented here at the scale this
//! project needs.  Each module is self-contained and unit/property tested.

pub mod args;
pub mod json;
pub mod logger;
pub mod proptest;
pub mod rng;
pub mod stats;
pub mod tomlmini;

pub use rng::Rng;
