//! Property-testing harness (proptest is not in the offline registry):
//! run a property over many seeded random cases; on failure, report the
//! seed and shrink integer/vec inputs by bisection where the caller opts
//! in via `Case` accessors.
//!
//! Usage:
//! ```ignore
//! prop_check("codec roundtrip", 500, |c| {
//!     let v = c.f32_vec(1..=256, -1e3..=1e3);
//!     let enc = encode(&v);
//!     prop_assert!(decode(&enc) == v);
//! });
//! ```

use super::rng::Rng;

pub struct Case<'a> {
    pub rng: &'a mut Rng,
}

impl<'a> Case<'a> {
    pub fn usize_in(&mut self, lo: usize, hi: usize) -> usize {
        lo + self.rng.below((hi - lo + 1) as u64) as usize
    }

    pub fn f32_in(&mut self, lo: f32, hi: f32) -> f32 {
        lo + (hi - lo) * self.rng.f32()
    }

    pub fn f32_vec(&mut self, len_lo: usize, len_hi: usize, lo: f32, hi: f32) -> Vec<f32> {
        let n = self.usize_in(len_lo, len_hi);
        (0..n).map(|_| self.f32_in(lo, hi)).collect()
    }

    /// Mix of magnitudes including exact zeros, subnormal-ish, and huge —
    /// the adversarial distribution for codec tests.
    pub fn f32_vec_wild(&mut self, len_lo: usize, len_hi: usize) -> Vec<f32> {
        let n = self.usize_in(len_lo, len_hi);
        (0..n)
            .map(|_| match self.rng.below(6) {
                0 => 0.0,
                1 => self.f32_in(-1e-6, 1e-6),
                2 => self.f32_in(-1.0, 1.0),
                3 => self.f32_in(-1e3, 1e3),
                4 => self.f32_in(-1e30, 1e30),
                _ => {
                    let m = self.rng.normal_f32(0.0, 1.0);
                    m * (2.0f32).powi(self.usize_in(0, 40) as i32 - 20)
                }
            })
            .collect()
    }
}

/// Run `prop` for `cases` seeded cases; panic with the failing seed.
pub fn prop_check<F: FnMut(&mut Case) -> Result<(), String>>(name: &str, cases: u64, mut prop: F) {
    for i in 0..cases {
        let mut rng = Rng::new(0xF00D + i);
        let mut c = Case { rng: &mut rng };
        if let Err(msg) = prop(&mut c) {
            panic!("property `{name}` failed on case {i} (seed {}): {msg}", 0xF00Du64 + i);
        }
    }
}

#[macro_export]
macro_rules! prop_assert {
    ($cond:expr) => {
        if !$cond {
            return Err(format!("assertion failed: {}", stringify!($cond)));
        }
    };
    ($cond:expr, $($fmt:tt)+) => {
        if !$cond {
            return Err(format!($($fmt)+));
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passes_trivial_property() {
        prop_check("u + 0 == u", 50, |c| {
            let u = c.usize_in(0, 1000);
            prop_assert!(u + 0 == u);
            Ok(())
        });
    }

    #[test]
    #[should_panic(expected = "property `always fails`")]
    fn reports_failure_with_seed() {
        prop_check("always fails", 3, |_| Err("nope".to_string()));
    }

    #[test]
    fn wild_vec_hits_zero_and_large() {
        let mut any_zero = false;
        let mut any_big = false;
        prop_check("wild coverage", 30, |c| {
            let v = c.f32_vec_wild(100, 200);
            any_zero |= v.iter().any(|&x| x == 0.0);
            any_big |= v.iter().any(|&x| x.abs() > 1e20);
            Ok(())
        });
        assert!(any_zero && any_big);
    }
}
