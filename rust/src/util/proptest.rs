//! Property-testing harness (proptest is not in the offline registry):
//! run a property over many seeded random cases; on failure, report the
//! seed and shrink integer/vec inputs by bisection where the caller opts
//! in via `Case` accessors.
//!
//! Usage:
//! ```ignore
//! prop_check("codec roundtrip", 500, |c| {
//!     let v = c.f32_vec(1..=256, -1e3..=1e3);
//!     let enc = encode(&v);
//!     prop_assert!(decode(&enc) == v);
//! });
//! ```

use super::rng::Rng;

pub struct Case<'a> {
    pub rng: &'a mut Rng,
}

impl<'a> Case<'a> {
    pub fn usize_in(&mut self, lo: usize, hi: usize) -> usize {
        lo + self.rng.below((hi - lo + 1) as u64) as usize
    }

    pub fn f32_in(&mut self, lo: f32, hi: f32) -> f32 {
        lo + (hi - lo) * self.rng.f32()
    }

    pub fn f32_vec(&mut self, len_lo: usize, len_hi: usize, lo: f32, hi: f32) -> Vec<f32> {
        let n = self.usize_in(len_lo, len_hi);
        (0..n).map(|_| self.f32_in(lo, hi)).collect()
    }

    /// Mix of magnitudes including exact zeros, subnormal-ish, and huge —
    /// the adversarial distribution for codec tests.
    pub fn f32_vec_wild(&mut self, len_lo: usize, len_hi: usize) -> Vec<f32> {
        let n = self.usize_in(len_lo, len_hi);
        (0..n).map(|_| self.wild_f32()).collect()
    }

    fn wild_f32(&mut self) -> f32 {
        match self.rng.below(6) {
            0 => 0.0,
            1 => self.f32_in(-1e-6, 1e-6),
            2 => self.f32_in(-1.0, 1.0),
            3 => self.f32_in(-1e3, 1e3),
            4 => self.f32_in(-1e30, 1e30),
            _ => {
                let m = self.rng.normal_f32(0.0, 1.0);
                m * (2.0f32).powi(self.usize_in(0, 40) as i32 - 20)
            }
        }
    }

    /// Seeded random row-major matrix with uniform entries in [lo, hi]:
    /// returns (data, rows, cols).  The 2-D generator for GEMM/model
    /// property tests (refmodel fwd/bwd, kernels).
    pub fn f32_mat(
        &mut self,
        rows_lo: usize,
        rows_hi: usize,
        cols_lo: usize,
        cols_hi: usize,
        lo: f32,
        hi: f32,
    ) -> (Vec<f32>, usize, usize) {
        let rows = self.usize_in(rows_lo, rows_hi);
        let cols = self.usize_in(cols_lo, cols_hi);
        let data = (0..rows * cols).map(|_| self.f32_in(lo, hi)).collect();
        (data, rows, cols)
    }

    /// [`Case::f32_mat`] with the wild-magnitude element distribution
    /// (zeros, subnormal-ish, huge) — the adversarial variant for
    /// quantization-facing matrix kernels.
    pub fn f32_mat_wild(
        &mut self,
        rows_lo: usize,
        rows_hi: usize,
        cols_lo: usize,
        cols_hi: usize,
    ) -> (Vec<f32>, usize, usize) {
        let rows = self.usize_in(rows_lo, rows_hi);
        let cols = self.usize_in(cols_lo, cols_hi);
        let data = (0..rows * cols).map(|_| self.wild_f32()).collect();
        (data, rows, cols)
    }
}

/// Shrink a failing 2-D case by row bisection: while `fails` keeps
/// returning true on a half, drop the other half; returns the smallest
/// failing (data, rows) found.  Column geometry is preserved — cols is
/// usually load-bearing (block sizes, contraction dims) — so only the
/// row count shrinks.  Callers opt in from a failing property to report
/// (or re-assert on) a minimal reproducer.
pub fn shrink_rows<F: FnMut(&[f32], usize) -> bool>(
    data: &[f32],
    rows: usize,
    cols: usize,
    mut fails: F,
) -> (Vec<f32>, usize) {
    let mut cur = data.to_vec();
    let mut r = rows;
    while r > 1 {
        let half = r / 2;
        let first = cur[..half * cols].to_vec();
        if fails(&first, half) {
            cur = first;
            r = half;
            continue;
        }
        let second = cur[(r - half) * cols..].to_vec();
        if fails(&second, half) {
            cur = second;
            r = half;
            continue;
        }
        break;
    }
    (cur, r)
}

/// Run `prop` for `cases` seeded cases; panic with the failing seed.
pub fn prop_check<F: FnMut(&mut Case) -> Result<(), String>>(name: &str, cases: u64, mut prop: F) {
    for i in 0..cases {
        let mut rng = Rng::new(0xF00D + i);
        let mut c = Case { rng: &mut rng };
        if let Err(msg) = prop(&mut c) {
            panic!("property `{name}` failed on case {i} (seed {}): {msg}", 0xF00Du64 + i);
        }
    }
}

#[macro_export]
macro_rules! prop_assert {
    ($cond:expr) => {
        if !$cond {
            return Err(format!("assertion failed: {}", stringify!($cond)));
        }
    };
    ($cond:expr, $($fmt:tt)+) => {
        if !$cond {
            return Err(format!($($fmt)+));
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passes_trivial_property() {
        prop_check("u + 0 == u", 50, |c| {
            let u = c.usize_in(0, 1000);
            prop_assert!(u + 0 == u);
            Ok(())
        });
    }

    #[test]
    #[should_panic(expected = "property `always fails`")]
    fn reports_failure_with_seed() {
        prop_check("always fails", 3, |_| Err("nope".to_string()));
    }

    #[test]
    fn f32_mat_shapes_and_ranges() {
        prop_check("f32_mat geometry", 40, |c| {
            let (d, r, cl) = c.f32_mat(2, 7, 3, 9, -2.0, 2.0);
            prop_assert!(d.len() == r * cl);
            prop_assert!((2..=7).contains(&r) && (3..=9).contains(&cl));
            prop_assert!(d.iter().all(|&v| (-2.0..=2.0).contains(&v)));
            let (dw, rw, cw) = c.f32_mat_wild(1, 4, 2, 5);
            prop_assert!(dw.len() == rw * cw);
            Ok(())
        });
    }

    #[test]
    fn shrink_rows_finds_minimal_failing_block() {
        // property fails whenever the matrix contains the poison value
        let cols = 4;
        let mut data = vec![0.0f32; 16 * cols];
        data[9 * cols + 2] = f32::INFINITY;
        let fails = |d: &[f32], _r: usize| d.iter().any(|v| v.is_infinite());
        let (min_d, min_r) = shrink_rows(&data, 16, cols, fails);
        assert_eq!(min_r, 1, "bisection should isolate the poisoned row");
        assert!(min_d.iter().any(|v| v.is_infinite()));
        // a case that never fails on halves stays untouched
        let (same, r) = shrink_rows(&data, 16, cols, |_, _| false);
        assert_eq!((same.len(), r), (data.len(), 16));
    }

    #[test]
    fn wild_vec_hits_zero_and_large() {
        let mut any_zero = false;
        let mut any_big = false;
        prop_check("wild coverage", 30, |c| {
            let v = c.f32_vec_wild(100, 200);
            any_zero |= v.iter().any(|&x| x == 0.0);
            any_big |= v.iter().any(|&x| x.abs() > 1e20);
            Ok(())
        });
        assert!(any_zero && any_big);
    }
}
