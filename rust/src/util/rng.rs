//! Deterministic PRNG: xoshiro256++ seeded via splitmix64, plus the
//! distributions the project needs (uniform, normal, Zipf, shuffle).
//!
//! Determinism contract: every sequence is a pure function of the seed, so
//! data pipelines, corpus generation, and experiments are exactly
//! reproducible across runs and across worker counts (workers derive
//! sub-seeds with `fork`).

/// xoshiro256++ PRNG (Blackman & Vigna).  Not cryptographic.
#[derive(Clone, Debug)]
pub struct Rng {
    s: [u64; 4],
}

fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9E3779B97F4A7C15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
    z ^ (z >> 31)
}

/// Counter-based hash (splitmix64 finalizer over `key + (i+1)·φ64`): a
/// stateless uniform u64 that depends only on `(key, i)`.  This is the
/// determinism backbone of stochastic rounding — the draw for element `i`
/// of tensor `key` is the same no matter which thread processes it, how
/// the sweep is chunked, or what ran before (mirrored bit-for-bit in
/// `python/compile/kernels/ref.py::np_counter_hash`).
#[inline]
pub fn counter_hash(key: u64, i: u64) -> u64 {
    let mut z = key.wrapping_add(i.wrapping_add(1).wrapping_mul(0x9E3779B97F4A7C15));
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
    z ^ (z >> 31)
}

/// Map a hash to a uniform f32 in [0, 1) using its top 24 bits (shifted
/// past the low bits so `unit_f32(counter_hash(..))` uses the
/// best-avalanched part of the word).
#[inline]
pub fn unit_f32(h: u64) -> f32 {
    ((h >> 40) as u32) as f32 * (1.0 / (1u32 << 24) as f32)
}

impl Rng {
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        Rng { s: [splitmix64(&mut sm), splitmix64(&mut sm), splitmix64(&mut sm), splitmix64(&mut sm)] }
    }

    /// Derive an independent child stream (for per-worker/per-shard rngs).
    pub fn fork(&mut self, tag: u64) -> Rng {
        Rng::new(self.next_u64() ^ tag.wrapping_mul(0x9E3779B97F4A7C15))
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let r = (self.s[0].wrapping_add(self.s[3]))
            .rotate_left(23)
            .wrapping_add(self.s[0]);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        r
    }

    #[inline]
    pub fn next_u32(&mut self) -> u32 {
        (self.next_u64() >> 32) as u32
    }

    /// Uniform in [0, n) without modulo bias (Lemire's method).
    pub fn below(&mut self, n: u64) -> u64 {
        assert!(n > 0);
        let mut x = self.next_u64();
        let mut m = (x as u128).wrapping_mul(n as u128);
        let mut l = m as u64;
        if l < n {
            let t = n.wrapping_neg() % n;
            while l < t {
                x = self.next_u64();
                m = (x as u128).wrapping_mul(n as u128);
                l = m as u64;
            }
        }
        (m >> 64) as u64
    }

    /// Uniform f64 in [0, 1).
    #[inline]
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform f32 in [0, 1).
    #[inline]
    pub fn f32(&mut self) -> f32 {
        (self.next_u32() >> 8) as f32 * (1.0 / (1u32 << 24) as f32)
    }

    /// Standard normal via Box-Muller.
    pub fn normal(&mut self) -> f64 {
        loop {
            let u = self.f64();
            if u > 1e-300 {
                let v = self.f64();
                return (-2.0 * u.ln()).sqrt() * (2.0 * std::f64::consts::PI * v).cos();
            }
        }
    }

    pub fn normal_f32(&mut self, mean: f32, std: f32) -> f32 {
        mean + std * self.normal() as f32
    }

    /// Fisher-Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i as u64 + 1) as usize;
            xs.swap(i, j);
        }
    }

    pub fn choice<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        &xs[self.below(xs.len() as u64) as usize]
    }
}

/// Zipf(s) sampler over ranks 0..n via precomputed CDF inversion — the
/// unigram backbone of the synthetic corpus (natural-language-like token
/// frequency decay).
pub struct Zipf {
    cdf: Vec<f64>,
}

impl Zipf {
    pub fn new(n: usize, s: f64) -> Self {
        assert!(n > 0);
        let mut cdf = Vec::with_capacity(n);
        let mut acc = 0.0;
        for k in 1..=n {
            acc += 1.0 / (k as f64).powf(s);
            cdf.push(acc);
        }
        let total = acc;
        for c in cdf.iter_mut() {
            *c /= total;
        }
        Zipf { cdf }
    }

    pub fn sample(&self, rng: &mut Rng) -> usize {
        let u = rng.f64();
        match self.cdf.binary_search_by(|c| c.partial_cmp(&u).unwrap()) {
            Ok(i) => i,
            Err(i) => i.min(self.cdf.len() - 1),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_for_seed() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_differ() {
        let mut a = Rng::new(1);
        let mut b = Rng::new(2);
        assert_ne!(
            (0..8).map(|_| a.next_u64()).collect::<Vec<_>>(),
            (0..8).map(|_| b.next_u64()).collect::<Vec<_>>()
        );
    }

    #[test]
    fn below_is_in_range_and_covers() {
        let mut r = Rng::new(7);
        let mut seen = [false; 10];
        for _ in 0..1000 {
            let x = r.below(10) as usize;
            assert!(x < 10);
            seen[x] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn uniform_mean_close() {
        let mut r = Rng::new(3);
        let m: f64 = (0..20000).map(|_| r.f64()).sum::<f64>() / 20000.0;
        assert!((m - 0.5).abs() < 0.01, "{m}");
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(4);
        let xs: Vec<f64> = (0..20000).map(|_| r.normal()).collect();
        let m = xs.iter().sum::<f64>() / xs.len() as f64;
        let v = xs.iter().map(|x| (x - m) * (x - m)).sum::<f64>() / xs.len() as f64;
        assert!(m.abs() < 0.03, "mean {m}");
        assert!((v - 1.0).abs() < 0.05, "var {v}");
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(5);
        let mut xs: Vec<u32> = (0..100).collect();
        r.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
        assert_ne!(xs, (0..100).collect::<Vec<_>>()); // astronomically unlikely
    }

    #[test]
    fn zipf_rank_ordering() {
        let z = Zipf::new(100, 1.1);
        let mut r = Rng::new(6);
        let mut counts = [0usize; 100];
        for _ in 0..50000 {
            counts[z.sample(&mut r)] += 1;
        }
        assert!(counts[0] > counts[10]);
        assert!(counts[1] > counts[50]);
        // head rank ~ proportional to 1/k^s: rank0/rank1 ≈ 2^1.1 ≈ 2.14
        let ratio = counts[0] as f64 / counts[1] as f64;
        assert!(ratio > 1.6 && ratio < 2.8, "{ratio}");
    }

    #[test]
    fn fork_streams_independent() {
        let mut root = Rng::new(9);
        let mut a = root.fork(1);
        let mut b = root.fork(2);
        assert_ne!(a.next_u64(), b.next_u64());
    }

    #[test]
    fn counter_hash_is_pure_and_decorrelated() {
        // pure function of (key, i)
        assert_eq!(counter_hash(7, 42), counter_hash(7, 42));
        // neighbouring counters and keys give unrelated words
        assert_ne!(counter_hash(7, 42), counter_hash(7, 43));
        assert_ne!(counter_hash(7, 42), counter_hash(8, 42));
        // i=0 is a real draw, not a fixed point of the key
        assert_ne!(counter_hash(7, 0), 7);
    }

    #[test]
    fn unit_f32_range_and_mean() {
        let mut sum = 0.0f64;
        const N: u64 = 20_000;
        for i in 0..N {
            let u = unit_f32(counter_hash(0xFEED, i));
            assert!((0.0..1.0).contains(&u), "{u}");
            sum += u as f64;
        }
        let mean = sum / N as f64;
        assert!((mean - 0.5).abs() < 0.01, "{mean}");
    }
}
