//! Streaming and batch statistics used by metrics, benches, and analysis.

/// Welford online mean/variance.
#[derive(Clone, Debug, Default)]
pub struct Welford {
    n: u64,
    mean: f64,
    m2: f64,
}

impl Welford {
    pub fn push(&mut self, x: f64) {
        self.n += 1;
        let d = x - self.mean;
        self.mean += d / self.n as f64;
        self.m2 += d * (x - self.mean);
    }

    pub fn count(&self) -> u64 {
        self.n
    }

    pub fn mean(&self) -> f64 {
        self.mean
    }

    pub fn var(&self) -> f64 {
        if self.n < 2 {
            0.0
        } else {
            self.m2 / (self.n - 1) as f64
        }
    }

    pub fn std(&self) -> f64 {
        self.var().sqrt()
    }
}

pub fn mean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return f64::NAN;
    }
    xs.iter().sum::<f64>() / xs.len() as f64
}

/// p in [0,1]; linear interpolation between order statistics.
pub fn percentile(xs: &[f64], p: f64) -> f64 {
    if xs.is_empty() {
        return f64::NAN;
    }
    let mut v: Vec<f64> = xs.to_vec();
    v.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let idx = p.clamp(0.0, 1.0) * (v.len() - 1) as f64;
    let lo = idx.floor() as usize;
    let hi = idx.ceil() as usize;
    if lo == hi {
        v[lo]
    } else {
        v[lo] + (idx - lo as f64) * (v[hi] - v[lo])
    }
}

pub fn median(xs: &[f64]) -> f64 {
    percentile(xs, 0.5)
}

/// Median absolute deviation (robust spread) — the bench harness's noise
/// estimate.
pub fn mad(xs: &[f64]) -> f64 {
    let m = median(xs);
    let dev: Vec<f64> = xs.iter().map(|x| (x - m).abs()).collect();
    median(&dev)
}

/// Histogram with fixed linear bins over [lo, hi); under/overflow counted.
#[derive(Clone, Debug)]
pub struct Histogram {
    pub lo: f64,
    pub hi: f64,
    pub bins: Vec<u64>,
    pub under: u64,
    pub over: u64,
}

impl Histogram {
    pub fn new(lo: f64, hi: f64, nbins: usize) -> Self {
        assert!(hi > lo && nbins > 0);
        Histogram { lo, hi, bins: vec![0; nbins], under: 0, over: 0 }
    }

    pub fn push(&mut self, x: f64) {
        if x < self.lo {
            self.under += 1;
        } else if x >= self.hi {
            self.over += 1;
        } else {
            let n = self.bins.len();
            let b = ((x - self.lo) / (self.hi - self.lo) * n as f64) as usize;
            self.bins[b.min(n - 1)] += 1;
        }
    }

    pub fn total(&self) -> u64 {
        self.bins.iter().sum::<u64>() + self.under + self.over
    }

    /// Render as sparkline-ish rows for terminal figures.
    pub fn render(&self, width: usize) -> String {
        let max = self.bins.iter().copied().max().unwrap_or(1).max(1);
        let mut s = String::new();
        for (i, &c) in self.bins.iter().enumerate() {
            let x0 = self.lo + (self.hi - self.lo) * i as f64 / self.bins.len() as f64;
            let bar = "#".repeat(((c as f64 / max as f64) * width as f64).round() as usize);
            s.push_str(&format!("{x0:>12.4e} | {bar} {c}\n"));
        }
        s
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn welford_matches_batch() {
        let xs = [1.0, 2.0, 3.0, 4.0, 10.0];
        let mut w = Welford::default();
        for x in xs {
            w.push(x);
        }
        assert!((w.mean() - 4.0).abs() < 1e-12);
        let batch_var = xs.iter().map(|x| (x - 4.0) * (x - 4.0)).sum::<f64>() / 4.0;
        assert!((w.var() - batch_var).abs() < 1e-12);
    }

    #[test]
    fn percentile_basics() {
        let xs = [1.0, 2.0, 3.0, 4.0];
        assert_eq!(percentile(&xs, 0.0), 1.0);
        assert_eq!(percentile(&xs, 1.0), 4.0);
        assert_eq!(median(&xs), 2.5);
    }

    #[test]
    fn mad_robust_to_outlier() {
        let xs = [1.0, 1.1, 0.9, 1.0, 100.0];
        assert!(mad(&xs) < 0.2);
    }

    #[test]
    fn histogram_counts() {
        let mut h = Histogram::new(0.0, 10.0, 10);
        for i in 0..10 {
            h.push(i as f64 + 0.5);
        }
        h.push(-1.0);
        h.push(11.0);
        assert_eq!(h.bins, vec![1; 10]);
        assert_eq!((h.under, h.over), (1, 1));
        assert_eq!(h.total(), 12);
    }
}
