//! TOML-subset config parser: enough of TOML for run configuration files —
//! `[table.subtable]` headers, `key = value` with strings, integers,
//! floats, booleans, and flat arrays, plus `#` comments.
//!
//! Values are exposed through dotted-path lookup (`cfg.get("train.steps")`).

use std::collections::BTreeMap;

#[derive(Clone, Debug, PartialEq)]
pub enum TomlValue {
    Str(String),
    Int(i64),
    Float(f64),
    Bool(bool),
    Arr(Vec<TomlValue>),
}

impl TomlValue {
    pub fn as_str(&self) -> Option<&str> {
        match self {
            TomlValue::Str(s) => Some(s),
            _ => None,
        }
    }
    pub fn as_i64(&self) -> Option<i64> {
        match self {
            TomlValue::Int(i) => Some(*i),
            _ => None,
        }
    }
    pub fn as_f64(&self) -> Option<f64> {
        match self {
            TomlValue::Float(f) => Some(*f),
            TomlValue::Int(i) => Some(*i as f64),
            _ => None,
        }
    }
    pub fn as_bool(&self) -> Option<bool> {
        match self {
            TomlValue::Bool(b) => Some(*b),
            _ => None,
        }
    }
}

#[derive(Clone, Debug, Default)]
pub struct TomlDoc {
    entries: BTreeMap<String, TomlValue>,
}

#[derive(Debug)]
pub struct TomlError {
    pub line: usize,
    pub msg: String,
}

impl std::fmt::Display for TomlError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "config error on line {}: {}", self.line, self.msg)
    }
}
impl std::error::Error for TomlError {}

impl TomlDoc {
    pub fn parse(src: &str) -> Result<TomlDoc, TomlError> {
        let mut doc = TomlDoc::default();
        let mut prefix = String::new();
        for (ln, raw) in src.lines().enumerate() {
            let line = strip_comment(raw).trim().to_string();
            if line.is_empty() {
                continue;
            }
            if let Some(table) = line.strip_prefix('[') {
                let table = table
                    .strip_suffix(']')
                    .ok_or_else(|| err(ln, "unterminated table header"))?
                    .trim();
                if table.is_empty() {
                    return Err(err(ln, "empty table name"));
                }
                prefix = table.to_string();
                continue;
            }
            let (k, v) = line
                .split_once('=')
                .ok_or_else(|| err(ln, "expected key = value"))?;
            let key = k.trim();
            if key.is_empty() {
                return Err(err(ln, "empty key"));
            }
            let full = if prefix.is_empty() { key.to_string() } else { format!("{prefix}.{key}") };
            let val = parse_value(v.trim(), ln)?;
            doc.entries.insert(full, val);
        }
        Ok(doc)
    }

    pub fn get(&self, dotted: &str) -> Option<&TomlValue> {
        self.entries.get(dotted)
    }

    pub fn str_or(&self, key: &str, default: &str) -> String {
        self.get(key).and_then(|v| v.as_str()).unwrap_or(default).to_string()
    }

    pub fn i64_or(&self, key: &str, default: i64) -> i64 {
        self.get(key).and_then(|v| v.as_i64()).unwrap_or(default)
    }

    pub fn f64_or(&self, key: &str, default: f64) -> f64 {
        self.get(key).and_then(|v| v.as_f64()).unwrap_or(default)
    }

    pub fn bool_or(&self, key: &str, default: bool) -> bool {
        self.get(key).and_then(|v| v.as_bool()).unwrap_or(default)
    }

    pub fn keys(&self) -> impl Iterator<Item = &str> {
        self.entries.keys().map(|s| s.as_str())
    }

    /// Merge another doc over this one (CLI overrides over file).
    pub fn merge_from(&mut self, other: TomlDoc) {
        for (k, v) in other.entries {
            self.entries.insert(k, v);
        }
    }

    pub fn set(&mut self, key: &str, v: TomlValue) {
        self.entries.insert(key.to_string(), v);
    }
}

fn err(ln: usize, msg: &str) -> TomlError {
    TomlError { line: ln + 1, msg: msg.to_string() }
}

fn strip_comment(line: &str) -> &str {
    // `#` starts a comment unless inside a quoted string.
    let mut in_str = false;
    for (i, c) in line.char_indices() {
        match c {
            '"' => in_str = !in_str,
            '#' if !in_str => return &line[..i],
            _ => {}
        }
    }
    line
}

fn parse_value(s: &str, ln: usize) -> Result<TomlValue, TomlError> {
    if s.is_empty() {
        return Err(err(ln, "empty value"));
    }
    if let Some(inner) = s.strip_prefix('"') {
        let inner = inner
            .strip_suffix('"')
            .ok_or_else(|| err(ln, "unterminated string"))?;
        return Ok(TomlValue::Str(inner.replace("\\n", "\n").replace("\\\"", "\"")));
    }
    if s == "true" {
        return Ok(TomlValue::Bool(true));
    }
    if s == "false" {
        return Ok(TomlValue::Bool(false));
    }
    if let Some(inner) = s.strip_prefix('[') {
        let inner = inner
            .strip_suffix(']')
            .ok_or_else(|| err(ln, "unterminated array"))?
            .trim();
        if inner.is_empty() {
            return Ok(TomlValue::Arr(vec![]));
        }
        let mut xs = Vec::new();
        for part in inner.split(',') {
            xs.push(parse_value(part.trim(), ln)?);
        }
        return Ok(TomlValue::Arr(xs));
    }
    let clean = s.replace('_', "");
    if let Ok(i) = clean.parse::<i64>() {
        return Ok(TomlValue::Int(i));
    }
    if let Ok(f) = clean.parse::<f64>() {
        return Ok(TomlValue::Float(f));
    }
    Err(err(ln, &format!("cannot parse value `{s}`")))
}

#[cfg(test)]
mod tests {
    use super::*;

    const SRC: &str = r#"
# run config
name = "quickstart"     # inline comment
steps = 1_000

[model]
preset = "gpt2-s-proxy"
lr = 6e-4
use_pallas = false

[schedule]
stages = [0.9, 0.1]
"#;

    #[test]
    fn parses_tables_and_types() {
        let d = TomlDoc::parse(SRC).unwrap();
        assert_eq!(d.str_or("name", ""), "quickstart");
        assert_eq!(d.i64_or("steps", 0), 1000);
        assert_eq!(d.str_or("model.preset", ""), "gpt2-s-proxy");
        assert!((d.f64_or("model.lr", 0.0) - 6e-4).abs() < 1e-12);
        assert!(!d.bool_or("model.use_pallas", true));
        match d.get("schedule.stages").unwrap() {
            TomlValue::Arr(xs) => assert_eq!(xs.len(), 2),
            _ => panic!(),
        }
    }

    #[test]
    fn comment_inside_string_kept() {
        let d = TomlDoc::parse(r##"k = "a # b""##).unwrap();
        assert_eq!(d.str_or("k", ""), "a # b");
    }

    #[test]
    fn merge_overrides() {
        let mut a = TomlDoc::parse("x = 1\ny = 2").unwrap();
        let b = TomlDoc::parse("y = 3").unwrap();
        a.merge_from(b);
        assert_eq!(a.i64_or("x", 0), 1);
        assert_eq!(a.i64_or("y", 0), 3);
    }

    #[test]
    fn errors_carry_line_numbers() {
        let e = TomlDoc::parse("ok = 1\nbad line").unwrap_err();
        assert_eq!(e.line, 2);
    }

    #[test]
    fn rejects_bad_values() {
        assert!(TomlDoc::parse("k = ").is_err());
        assert!(TomlDoc::parse("k = [1, 2").is_err());
        assert!(TomlDoc::parse("k = \"x").is_err());
        assert!(TomlDoc::parse("k = zzz").is_err());
    }
}
