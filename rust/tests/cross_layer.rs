//! Cross-layer consistency: the rust `formats` module must agree
//! bit-for-bit with the python `compile.formats` implementation that the
//! AOT artifacts were built from (reference vectors emitted by aot.py).
//!
//! This is the contract that makes the rust-side analysis (Fig. 1(b)
//! underflow rates) and FP4/FP8 checkpoint codecs interchangeable with
//! the in-graph quantization.

use std::path::Path;

use fp4train::formats::{fake_quant_rows, FpFormat, Granularity};
use fp4train::util::json::Json;

fn reference() -> Option<Json> {
    let p = Path::new("artifacts/formats_reference.json");
    if !p.exists() {
        eprintln!("skipping: run `make artifacts` first");
        return None;
    }
    Some(Json::parse(&std::fs::read_to_string(p).unwrap()).unwrap())
}

fn floats(j: &Json, key: &str) -> Vec<f32> {
    j.get(key)
        .and_then(|a| a.as_arr())
        .unwrap_or_else(|| panic!("missing {key}"))
        .iter()
        .map(|v| v.as_f64().unwrap() as f32)
        .collect()
}

#[test]
fn grid_projection_bit_exact_vs_python() {
    let Some(j) = reference() else { return };
    let inputs = floats(&j, "inputs");
    for name in ["fp4_e2m1", "fp8_e4m3", "fp8_e5m2"] {
        let fmt = FpFormat::by_name(name).unwrap();
        let want = floats(&j, &format!("grid_{name}"));
        assert_eq!(inputs.len(), want.len());
        for (i, (&x, &w)) in inputs.iter().zip(&want).enumerate() {
            let got = fmt.quantize(x);
            assert!(
                got == w || (got == 0.0 && w == 0.0),
                "{name}[{i}]: quantize({x}) = {got}, python says {w}"
            );
        }
    }
}

#[test]
fn block_fake_quant_bit_exact_vs_python() {
    let Some(j) = reference() else { return };
    let inputs = floats(&j, "inputs");
    let want = floats(&j, "block_fp4_rows4_cols256");
    let x = &inputs[..1024];
    let got = fake_quant_rows(
        x,
        4,
        256,
        FpFormat::by_name("fp4").unwrap(),
        Granularity::PerBlock(128),
    );
    let mut mismatches = 0;
    for (i, (&g, &w)) in got.iter().zip(&want).enumerate() {
        if g != w {
            // scales are not powers of two; allow 1-ulp divergence from
            // fused-multiply ordering but nothing more
            let ulp = (g - w).abs() / g.abs().max(f32::MIN_POSITIVE);
            assert!(ulp < 3e-7, "idx {i}: rust {g} vs python {w}");
            mismatches += 1;
        }
    }
    assert!(
        mismatches < want.len() / 100,
        "too many 1-ulp mismatches: {mismatches}/{}",
        want.len()
    );
}

#[test]
fn codec_roundtrip_matches_python_grid() {
    let Some(j) = reference() else { return };
    let inputs = floats(&j, "inputs");
    for name in ["fp4_e2m1", "fp8_e4m3"] {
        let fmt = FpFormat::by_name(name).unwrap();
        let want = floats(&j, &format!("grid_{name}"));
        for (&x, &w) in inputs.iter().zip(&want) {
            let via = fp4train::formats::codec::decode(fmt, fp4train::formats::codec::encode(fmt, x));
            assert!(via == w || (via == 0.0 && w == 0.0), "{name}: {x} -> {via} vs {w}");
        }
    }
}
