//! Integration: the L3 coordinator end-to-end — schedule switch, DP
//! equivalence, checkpoint resume.
//!
//! Every test is `#[ignore]`d: they require *executing* PJRT artifacts,
//! which the compile-only `vendor/xla-stub` crate cannot do.  Run with
//! `cargo test -- --ignored` once the real xla_extension crate is
//! vendored; `tests/refmodel_determinism.rs` pins the schedule-switch
//! and training-loop contracts on the `--host` engine in the meantime.

use std::path::Path;

use fp4train::config::RunConfig;
use fp4train::coordinator::dp::DataParallel;
use fp4train::coordinator::trainer::{build_dataset, Trainer};
use fp4train::runtime::state::TrainState;
use fp4train::runtime::{download_f32, Runtime};

fn runtime() -> Option<Runtime> {
    let dir = Path::new("artifacts");
    if !dir.join("manifest.json").exists() {
        eprintln!("skipping: run `make artifacts` first");
        return None;
    }
    Some(Runtime::open(dir).expect("runtime"))
}

fn tiny_cfg(steps: u64) -> RunConfig {
    let mut cfg = RunConfig::default();
    cfg.steps = steps;
    cfg.eval_every = steps;
    cfg.log_every = steps;
    cfg.data.n_docs = 400;
    cfg.out_dir = std::env::temp_dir().join("fp4runs").to_str().unwrap().to_string();
    cfg
}

#[test]
#[ignore = "needs xla_extension (PJRT execution; the stub xla crate cannot run artifacts — see ROADMAP)"]
fn trainer_descends_and_switches_stage() {
    let Some(rt) = runtime() else { return };
    let mut cfg = tiny_cfg(14);
    cfg.target_precision_frac = 0.3; // stage 2 for the last ~4 steps
    let res = Trainer::new(&rt, cfg).run(None).unwrap();
    assert!(res.final_val_nll.is_finite());
    let stages: Vec<u8> = res.metrics.steps.iter().map(|r| r.stage).collect();
    assert_eq!(stages[..9], vec![0u8; 9][..]); // 14 - floor(14*0.3)=4 -> 10 low
    assert!(stages.ends_with(&[1, 1, 1, 1]), "{stages:?}");
    let first = res.metrics.steps[0].loss;
    let last = res.metrics.steps.last().unwrap().loss;
    assert!(last < first, "loss {first} -> {last}");
    // metrics CSVs written
    assert!(res.metrics.steps.len() == 14);
}

#[test]
#[ignore = "needs xla_extension (PJRT execution; the stub xla crate cannot run artifacts — see ROADMAP)"]
fn dp_two_workers_matches_sequential_grad_average() {
    let Some(rt) = runtime() else { return };
    let cfg = tiny_cfg(1);
    let (ds, _) = build_dataset(&rt, &cfg).unwrap();

    // DP step with 2 workers
    let dp = DataParallel::new(&rt, "gpt2-s-proxy", "ours", 2).unwrap();
    let st = TrainState::init(&rt, "gpt2-s-proxy", "ours", 5).unwrap();
    let (st_dp, loss_dp, _) = dp.step(st, &ds, 0).unwrap();

    // manual: same two shards through the 1-worker grad exe, averaged
    let grad_exe = rt.load("gpt2-s-proxy", "ours", "grad").unwrap();
    let apply_exe = rt.load("gpt2-s-proxy", "ours", "apply").unwrap();
    let st2 = TrainState::init(&rt, "gpt2-s-proxy", "ours", 5).unwrap();
    let mut gs = Vec::new();
    let mut losses = Vec::new();
    for w in 0..2 {
        let b = ds.train_batch(0, w, 2);
        let bb = rt.upload_i32(&b).unwrap();
        let mut args = st2.param_refs();
        args.push(&bb);
        let mut out = grad_exe.run(&args).unwrap();
        losses.push(download_f32(&out.pop().unwrap()).unwrap().item());
        gs.push(out.iter().map(|b| download_f32(b).unwrap()).collect::<Vec<_>>());
    }
    let mean = fp4train::coordinator::dp::allreduce_mean(&mut gs);
    let bufs: Vec<_> = mean.iter().map(|t| rt.upload_f32(t).unwrap()).collect();
    let (st_manual, _) = st2.apply_step(&apply_exe, &bufs).unwrap();

    assert!((loss_dp - (losses[0] + losses[1]) / 2.0).abs() < 1e-6);
    for (a, b) in st_dp.params().iter().zip(st_manual.params()) {
        let (ta, tb) = (download_f32(a).unwrap(), download_f32(b).unwrap());
        for (x, y) in ta.data.iter().zip(&tb.data) {
            assert!((x - y).abs() < 1e-6);
        }
    }
}

#[test]
#[ignore = "needs xla_extension (PJRT execution; the stub xla crate cannot run artifacts — see ROADMAP)"]
fn checkpoint_resume_reproduces_uninterrupted_run() {
    let Some(rt) = runtime() else { return };
    let dir = std::env::temp_dir().join("fp4ckpt_resume");
    let _ = std::fs::remove_dir_all(&dir);

    // uninterrupted 6-step run
    let mut cfg = tiny_cfg(6);
    cfg.seed = 9;
    cfg.target_precision_frac = 0.0;
    let res_full = Trainer::new(&rt, cfg.clone()).run(None).unwrap();

    // interrupted: 3 steps + checkpoint, then resume to 6
    let mut cfg_a = cfg.clone();
    cfg_a.steps = 3;
    cfg_a.checkpoint_every = 3;
    cfg_a.checkpoint_dir = dir.to_str().unwrap().to_string();
    Trainer::new(&rt, cfg_a).run(None).unwrap();
    let ckpt = dir.join("gpt2-s-proxy__ours__3.ckpt");
    assert!(ckpt.exists());
    let res_resumed = Trainer::new(&rt, cfg).run(Some(ckpt.to_str().unwrap())).unwrap();

    // same final losses (identical batches + f32 checkpoint)
    let l_full = res_full.metrics.steps.last().unwrap().loss;
    let l_res = res_resumed.metrics.steps.last().unwrap().loss;
    assert!((l_full - l_res).abs() < 1e-5, "{l_full} vs {l_res}");
}
