//! Integration: AOT artifacts × PJRT runtime — init, train, grad/apply
//! equivalence, eval, and device-resident chaining.
//!
//! Every test is `#[ignore]`d: they require *executing* PJRT artifacts,
//! which the compile-only `vendor/xla-stub` crate cannot do (and with no
//! artifacts directory they would silently skip — visible `ignored`
//! counts are honest signal, silent passes are not).  Run with
//! `cargo test -- --ignored` once the real xla_extension crate is
//! vendored and `make artifacts` has been run; the `--host` refmodel
//! path (`tests/refmodel_*.rs`) covers the executable training contract
//! in the meantime.

use std::path::Path;

use fp4train::data::batcher::{DatasetConfig, TokenDataset};
use fp4train::runtime::state::{eval_nll, TrainState};
use fp4train::runtime::{download_f32, Runtime};
use fp4train::tensor::TensorI32;

fn runtime() -> Option<Runtime> {
    let dir = Path::new("artifacts");
    if !dir.join("manifest.json").exists() {
        eprintln!("skipping: run `make artifacts` first");
        return None;
    }
    Some(Runtime::open(dir).expect("runtime"))
}

fn fake_batch(rt: &Runtime, model: &str, seed: u64) -> TensorI32 {
    let info = rt.manifest.model(model).unwrap();
    let b = rt.manifest.batch;
    let tokens: Vec<i32> = (0..(b * (info.seq + 1)) as u64)
        .map(|i| ((i.wrapping_mul(2654435761).wrapping_add(seed * 97)) % info.vocab as u64) as i32)
        .collect();
    TensorI32::from_vec(&[b, info.seq + 1], tokens)
}

#[test]
#[ignore = "needs xla_extension (PJRT execution; the stub xla crate cannot run artifacts — see ROADMAP)"]
fn init_produces_manifest_shapes() {
    let Some(rt) = runtime() else { return };
    let st = TrainState::init(&rt, "gpt2-s-proxy", "ours", 7).unwrap();
    let info = rt.manifest.model("gpt2-s-proxy").unwrap();
    assert_eq!(st.n_params, info.params.len());
    for (buf, spec) in st.params().iter().zip(&info.params) {
        let t = download_f32(buf).unwrap();
        assert_eq!(t.shape, spec.shape, "param {}", spec.name);
    }
    assert_eq!(st.step().unwrap(), 0);
}

#[test]
#[ignore = "needs xla_extension (PJRT execution; the stub xla crate cannot run artifacts — see ROADMAP)"]
fn init_is_seed_deterministic() {
    let Some(rt) = runtime() else { return };
    let a = TrainState::init(&rt, "gpt2-s-proxy", "ours", 3).unwrap();
    let b = TrainState::init(&rt, "gpt2-s-proxy", "ours", 3).unwrap();
    let c = TrainState::init(&rt, "gpt2-s-proxy", "ours", 4).unwrap();
    // compare a randomly initialized tensor (biases/gains are constant)
    let info = rt.manifest.model("gpt2-s-proxy").unwrap();
    let i = info.params.iter().position(|p| p.name == "wte").unwrap();
    let ta = download_f32(&a.params()[i]).unwrap();
    let tb = download_f32(&b.params()[i]).unwrap();
    let tc = download_f32(&c.params()[i]).unwrap();
    assert_eq!(ta.data, tb.data);
    assert_ne!(ta.data, tc.data);
}

#[test]
#[ignore = "needs xla_extension (PJRT execution; the stub xla crate cannot run artifacts — see ROADMAP)"]
fn train_step_reduces_loss_on_repeated_batch() {
    let Some(rt) = runtime() else { return };
    let exe = rt.load("gpt2-s-proxy", "ours", "train").unwrap();
    let mut st = TrainState::init(&rt, "gpt2-s-proxy", "ours", 0).unwrap();
    let batch = rt.upload_i32(&fake_batch(&rt, "gpt2-s-proxy", 1)).unwrap();
    let mut losses = Vec::new();
    for _ in 0..6 {
        let (st2, loss, gnorm) = st.train_step(&exe, &batch).unwrap();
        assert!(loss.is_finite() && gnorm.is_finite());
        losses.push(loss);
        st = st2;
    }
    assert_eq!(st.step().unwrap(), 6);
    assert!(
        losses.last().unwrap() < &(losses[0] - 0.05),
        "no descent: {losses:?}"
    );
    // loss at init ≈ ln(vocab)
    let vocab = rt.manifest.model("gpt2-s-proxy").unwrap().vocab as f32;
    assert!((losses[0] - vocab.ln()).abs() < 1.0, "{}", losses[0]);
}

#[test]
#[ignore = "needs xla_extension (PJRT execution; the stub xla crate cannot run artifacts — see ROADMAP)"]
fn grad_then_apply_matches_fused_train() {
    let Some(rt) = runtime() else { return };
    let train = rt.load("gpt2-s-proxy", "ours", "train").unwrap();
    let grad = rt.load("gpt2-s-proxy", "ours", "grad").unwrap();
    let apply = rt.load("gpt2-s-proxy", "ours", "apply").unwrap();
    let batch_t = fake_batch(&rt, "gpt2-s-proxy", 2);

    // fused path
    let st_a = TrainState::init(&rt, "gpt2-s-proxy", "ours", 1).unwrap();
    let batch = rt.upload_i32(&batch_t).unwrap();
    let (st_a, loss_fused, _) = st_a.train_step(&train, &batch).unwrap();

    // split path
    let st_b = TrainState::init(&rt, "gpt2-s-proxy", "ours", 1).unwrap();
    let mut args = st_b.param_refs();
    args.push(&batch);
    let mut gout = grad.run(&args).unwrap();
    let loss_buf = gout.pop().unwrap();
    let loss_split = download_f32(&loss_buf).unwrap().item();
    let (st_b, _) = st_b.apply_step(&apply, &gout).unwrap();

    assert!((loss_fused - loss_split).abs() < 1e-5, "{loss_fused} vs {loss_split}");
    for (a, b) in st_a.params().iter().zip(st_b.params()) {
        let (ta, tb) = (download_f32(a).unwrap(), download_f32(b).unwrap());
        for (x, y) in ta.data.iter().zip(&tb.data) {
            assert!((x - y).abs() < 1e-6, "{x} vs {y}");
        }
    }
}

#[test]
#[ignore = "needs xla_extension (PJRT execution; the stub xla crate cannot run artifacts — see ROADMAP)"]
fn eval_full_precision_near_log_vocab_at_init() {
    let Some(rt) = runtime() else { return };
    let eval = rt.load("gpt2-s-proxy", "ours", "eval").unwrap();
    let st = TrainState::init(&rt, "gpt2-s-proxy", "ours", 0).unwrap();
    let info = rt.manifest.model("gpt2-s-proxy").unwrap();
    let tokens: Vec<i32> = (0..200_000).map(|i| (i % 512) as i32).collect();
    let ds = TokenDataset::new(
        tokens,
        DatasetConfig { seq: info.seq, batch: rt.manifest.batch, val_frac: 0.2, seed: 0 },
    );
    let nll = eval_nll(&rt, &eval, &st, &ds.val_batches()[..2]).unwrap();
    assert!((nll - (512f64).ln()).abs() < 1.0, "{nll}");
}

#[test]
#[ignore = "needs xla_extension (PJRT execution; the stub xla crate cannot run artifacts — see ROADMAP)"]
fn pallas_artifact_runs_and_matches_jnp_variant() {
    let Some(rt) = runtime() else { return };
    let jnp = rt.load("gpt2-s-proxy", "ours", "train").unwrap();
    let pal = rt.load_variant("gpt2-s-proxy", "ours", "train", true).unwrap();
    let batch = rt.upload_i32(&fake_batch(&rt, "gpt2-s-proxy", 3)).unwrap();

    let st1 = TrainState::init(&rt, "gpt2-s-proxy", "ours", 2).unwrap();
    let (_, loss_jnp, _) = st1.train_step(&jnp, &batch).unwrap();
    let st2 = TrainState::init(&rt, "gpt2-s-proxy", "ours", 2).unwrap();
    let (_, loss_pal, _) = st2.train_step(&pal, &batch).unwrap();
    assert!(
        (loss_jnp - loss_pal).abs() < 1e-4,
        "jnp {loss_jnp} vs pallas {loss_pal}"
    );
}

#[test]
#[ignore = "needs xla_extension (PJRT execution; the stub xla crate cannot run artifacts — see ROADMAP)"]
fn capture_step_shapes() {
    let Some(rt) = runtime() else { return };
    let cap = rt.load("gpt2-s-proxy", "ours", "capture").unwrap();
    let st = TrainState::init(&rt, "gpt2-s-proxy", "ours", 0).unwrap();
    let batch = rt.upload_i32(&fake_batch(&rt, "gpt2-s-proxy", 4)).unwrap();
    let mut args = st.param_refs();
    args.push(&batch);
    let out = cap.run(&args).unwrap();
    let info = rt.manifest.model("gpt2-s-proxy").unwrap();
    let attn = download_f32(&out[0]).unwrap();
    assert_eq!(attn.shape, vec![info.seq, info.seq]);
    // rows sum to 1 (softmax)
    let row: f32 = attn.data[..info.seq].iter().sum();
    assert!((row - 1.0).abs() < 1e-4, "{row}");
}
