//! Integration: fused/LUT/parallel kernels vs the scalar reference across
//! the public API — every `Granularity`, odd geometries, adversarial
//! magnitudes, and the end-to-end quantize→save-shape→dequantize chain.
//! These are the guardrails that let callers (checkpointing, probes,
//! analysis) switch to the fast paths without a numerics audit.

use fp4train::formats::codec;
use fp4train::formats::{fake_quant_rows, Granularity, FP4_E2M1, FP8_E4M3, FP8_E5M2};
use fp4train::kernels::{
    decode_fast, encode_fast, fake_quant_rows_auto, fake_quant_rows_fast, matmul_bias_into,
    matmul_f32, matmul_into, qgemm, qgemm_bt, qgemm_bt_into, qgemm_into, quantize_pack_rows,
    quantize_pack_rows_auto, Workspace,
};
use fp4train::quant::{self, GranSpec};
use fp4train::tensor::Tensor;
use fp4train::util::rng::Rng;

fn wild(n: usize, seed: u64) -> Vec<f32> {
    let mut rng = Rng::new(seed);
    (0..n)
        .map(|i| match i % 5 {
            0 => 0.0,
            1 => rng.normal_f32(0.0, 1e-5),
            2 => rng.normal_f32(0.0, 1.0),
            3 => rng.normal_f32(0.0, 1e4),
            _ => -rng.normal_f32(0.0, 0.02),
        })
        .collect()
}

fn bits(v: &[f32]) -> Vec<u32> {
    v.iter().map(|x| x.to_bits()).collect()
}

#[test]
fn fused_equals_scalar_every_granularity_and_format() {
    for fmt in [FP4_E2M1, FP8_E4M3, FP8_E5M2] {
        for (rows, cols) in [(1, 64), (7, 96), (16, 129), (3, 31)] {
            let x = wild(rows * cols, rows as u64 * 31 + cols as u64);
            for g in [
                Granularity::PerTensor,
                Granularity::PerRow,
                Granularity::PerBlock(32),
                Granularity::PerBlock(43),
            ] {
                let fast = fake_quant_rows_fast(&x, rows, cols, fmt, g);
                let auto = fake_quant_rows_auto(&x, rows, cols, fmt, g);
                let slow = fake_quant_rows(&x, rows, cols, fmt, g);
                assert_eq!(bits(&fast), bits(&slow), "{} {rows}x{cols} {g:?}", fmt.name);
                assert_eq!(bits(&auto), bits(&slow), "{} {rows}x{cols} {g:?} auto", fmt.name);
            }
        }
    }
}

#[test]
fn parallel_kernels_equal_serial_at_scale() {
    // big enough to cross PAR_MIN_ELEMS with both even and odd group sizes
    for (rows, cols) in [(1024, 128), (520, 129)] {
        let x = wild(rows * cols, 99);
        for fmt in [FP4_E2M1, FP8_E4M3] {
            for g in [Granularity::PerRow, Granularity::PerBlock(43), Granularity::PerBlock(32)] {
                let (pp, ps) = quantize_pack_rows_auto(&x, rows, cols, fmt, g);
                let (sp, ss) = quantize_pack_rows(&x, rows, cols, fmt, g);
                assert_eq!(pp, sp, "{} {rows}x{cols} {g:?} packed", fmt.name);
                assert_eq!(bits(&ps), bits(&ss), "{} {rows}x{cols} {g:?} scales", fmt.name);
            }
        }
    }
}

#[test]
fn quantize_tensor_api_matches_scalar_reference() {
    for (shape, g) in [
        (vec![64usize, 256], GranSpec::PerBlock(128)),
        (vec![8, 4, 33], GranSpec::PerRow),
        (vec![512], GranSpec::PerTensor),
    ] {
        let n: usize = shape.iter().product();
        let t = Tensor::from_vec(&shape, wild(n, n as u64));
        for fmt in [FP4_E2M1, FP8_E4M3] {
            let fast = quant::quantize(&t, fmt, g);
            let slow = quant::quantize_scalar(&t, fmt, g);
            assert_eq!(fast.packed, slow.packed, "{} {shape:?}", fmt.name);
            assert_eq!(bits(&fast.scales), bits(&slow.scales), "{} {shape:?}", fmt.name);
            // and the LUT dequantize inverts both identically
            assert_eq!(
                bits(&quant::dequantize(&fast).data),
                bits(&quant::dequantize(&slow).data)
            );
        }
    }
}

#[test]
fn codec_fast_paths_agree_on_all_codes() {
    for fmt in [FP4_E2M1, FP8_E4M3, FP8_E5M2] {
        let n_codes = 1u16 << fmt.bits();
        for c in 0..n_codes {
            let c = c as u8;
            assert_eq!(
                decode_fast(fmt, c).to_bits(),
                codec::decode(fmt, c).to_bits(),
                "{} code {c}",
                fmt.name
            );
            // re-encoding the decoded value is stable through both paths
            let v = codec::decode(fmt, c);
            assert_eq!(encode_fast(fmt, v), codec::encode(fmt, v), "{} code {c}", fmt.name);
        }
    }
}

#[test]
fn qgemm_equals_dequant_matmul_across_formats_grans_and_shapes() {
    // tile-edge shapes (QKB=256, QJB=512) plus one shape past the parallel
    // threshold so the column-striped threaded path is covered
    let shapes = [(2usize, 33usize, 7usize), (3, 257, 513), (5, 256, 512), (64, 512, 640)];
    for fmt in [FP4_E2M1, FP8_E4M3, FP8_E5M2] {
        for &(m, k, n) in &shapes {
            let a = wild(m * k, 7 * m as u64 + k as u64);
            let bdata = wild(k * n, 11 * k as u64 + n as u64);
            for g in [GranSpec::PerTensor, GranSpec::PerRow, GranSpec::PerBlock(32)] {
                let q = quant::quantize_rows(&bdata, k, n, fmt, g);
                let got = qgemm(&a, &q, m, k, n);
                let want = matmul_f32(&a, &quant::dequantize(&q).data, m, k, n);
                assert_eq!(bits(&got), bits(&want), "{} {m}x{k}x{n} {g:?}", fmt.name);
            }
        }
    }
}

#[test]
fn qgemm_bt_equals_transposed_dequant_matmul_across_formats_grans_and_shapes() {
    // the transposed orientation: B stored (n, k), scale groups along the
    // trailing storage axis = the contraction axis K (the paper's §3.2
    // weight geometry).  Oracle: materialize dequantize(q)ᵀ, plain matmul.
    // Same tile-edge shapes as the as-stored suite plus one past the
    // parallel threshold (column-striped pooled path).
    let shapes = [(2usize, 33usize, 7usize), (3, 257, 513), (5, 256, 512), (64, 512, 640)];
    for fmt in [FP4_E2M1, FP8_E4M3, FP8_E5M2] {
        for &(m, k, n) in &shapes {
            let a = wild(m * k, 13 * m as u64 + k as u64);
            let bdata = wild(n * k, 17 * k as u64 + n as u64);
            for g in [GranSpec::PerTensor, GranSpec::PerRow, GranSpec::PerBlock(32)] {
                let q = quant::quantize_rows(&bdata, n, k, fmt, g);
                let got = qgemm_bt(&a, &q, m, k, n);
                let want = matmul_f32(&a, &quant::dequantize(&q).transpose2().data, m, k, n);
                assert_eq!(bits(&got), bits(&want), "{} {m}x{k}x{n} {g:?} bt", fmt.name);
            }
        }
    }
}

#[test]
fn transposed_quantize_equals_quantize_of_transpose_at_parallel_scale() {
    // past PAR_MIN_ELEMS so the row-fanned pool path runs (the serial
    // path is property-tested in quant's module tests); oracle is the
    // fused quantize of an explicitly materialized transpose
    let (rows, cols) = (520usize, 257usize);
    let x = wild(rows * cols, 81);
    let mut xt = Vec::new();
    fp4train::tensor::transpose_into(&x, rows, cols, &mut xt);
    for fmt in [FP4_E2M1, FP8_E4M3] {
        for g in [GranSpec::PerTensor, GranSpec::PerRow, GranSpec::PerBlock(8)] {
            let t = quant::quantize_rows_t(&x, rows, cols, fmt, g);
            let want = quant::quantize_rows(&xt, cols, rows, fmt, g);
            assert_eq!(t.packed, want.packed, "{} {g:?} codes", fmt.name);
            assert_eq!(bits(&t.scales), bits(&want.scales), "{} {g:?} scales", fmt.name);
        }
    }
}

#[test]
fn qgemm_bt_quantize_rows_t_roundtrip_is_the_qlinear_contract() {
    // end to end across the public API: pack a logical (k, n) weight
    // K-grouped with quantize_rows_t, run the forward orientation through
    // qgemm_bt and the dx orientation through qgemm on the SAME tensor,
    // and pin both against the fake-quant + f32-matmul oracle bit for bit
    let (m, k, n) = (5usize, 64usize, 48usize);
    let x = wild(m * k, 71);
    let g = wild(m * n, 72);
    let w = wild(k * n, 73);
    for fmt in [FP4_E2M1, FP8_E4M3] {
        let q = quant::quantize_rows_t(&w, k, n, fmt, GranSpec::PerBlock(16));
        assert_eq!(q.rows_cols(), (n, k));
        // dequantize(q) is fake_quant(wᵀ): fake-quant wᵀ via the scalar
        // reference, transpose back to (k, n) for the forward oracle
        let wt: Vec<f32> = {
            let mut t = Vec::new();
            fp4train::tensor::transpose_into(&w, k, n, &mut t);
            fake_quant_rows(&t, n, k, fmt, Granularity::PerBlock(16))
        };
        let mut wq = Vec::new();
        fp4train::tensor::transpose_into(&wt, n, k, &mut wq); // (k, n)
        let mut ws = Workspace::new();
        let mut y = vec![0.0f32; m * n];
        qgemm_bt_into(&x, &q, m, k, n, &mut y, &mut ws);
        assert_eq!(bits(&y), bits(&matmul_f32(&x, &wq, m, k, n)), "{} fwd", fmt.name);
        let mut dx = vec![0.0f32; m * k];
        qgemm_into(&g, &q, m, n, k, &mut dx, &mut ws);
        assert_eq!(bits(&dx), bits(&matmul_f32(&g, &wt, m, n, k)), "{} dx", fmt.name);
    }
}

#[test]
fn workspace_and_into_buffers_are_reusable_bitwise() {
    let mut rng = Rng::new(23);
    let (m, k, n) = (6usize, 300usize, 40usize);
    let a: Vec<f32> = (0..m * k).map(|_| rng.normal_f32(0.0, 1.0)).collect();
    let bdata: Vec<f32> = (0..k * n).map(|_| rng.normal_f32(0.0, 1.0)).collect();
    let bias: Vec<f32> = (0..n).map(|_| rng.normal_f32(0.0, 1.0)).collect();
    // f32 path: _into with a dirty reused buffer, bias folded in
    let mut out = vec![f32::NAN; m * n];
    matmul_into(&a, &bdata, m, k, n, &mut out);
    let mut want = matmul_f32(&a, &bdata, m, k, n);
    assert_eq!(bits(&out), bits(&want));
    matmul_bias_into(&a, &bdata, &bias, m, k, n, &mut out);
    for r in 0..m {
        for j in 0..n {
            want[r * n + j] += bias[j];
        }
    }
    assert_eq!(bits(&out), bits(&want));
    // packed path: one workspace across repeated + reshaped calls
    let q = quant::quantize_rows(&bdata, k, n, FP4_E2M1, GranSpec::PerBlock(32));
    let mut ws = Workspace::new();
    let fresh = qgemm(&a, &q, m, k, n);
    for _ in 0..2 {
        qgemm_into(&a, &q, m, k, n, &mut out, &mut ws);
        assert_eq!(bits(&out), bits(&fresh));
    }
}

#[test]
fn blocked_matmul_is_bitexact_through_tensor_api() {
    let mut rng = Rng::new(17);
    let a = Tensor::randn(&[33, 257], 1.0, &mut rng);
    let b = Tensor::randn(&[257, 19], 1.0, &mut rng);
    let got = a.matmul(&b);
    // naive oracle
    let mut want = vec![0.0f32; 33 * 19];
    for i in 0..33 {
        for kk in 0..257 {
            let av = a.data[i * 257 + kk];
            if av == 0.0 {
                continue;
            }
            for j in 0..19 {
                want[i * 19 + j] += av * b.data[kk * 19 + j];
            }
        }
    }
    assert_eq!(bits(&got.data), bits(&want));
    assert_eq!(got.shape, vec![33, 19]);
    // direct slice API too
    assert_eq!(bits(&matmul_f32(&a.data, &b.data, 33, 257, 19)), bits(&want));
}
