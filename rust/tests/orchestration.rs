//! Durable orchestration end-to-end: crash → resume is bit-identical to an
//! uninterrupted run (the ROADMAP's headline verify), the run store's
//! lease machinery survives process death, and corrupted checkpoints fail
//! loudly with the offending path.
//!
//! The fault sweep drives `TrainOptions::fault_at` (the in-process form of
//! `PALLAS_FAULT`) at three structurally different steps: before the first
//! checkpoint (full replay from init), mid-run between checkpoints, and
//! exactly at the §3.3 stage boundary where the recipe swaps to the
//! target.  Every surviving loss bit and every final master-parameter bit
//! must match the uninterrupted reference.

use std::path::{Path, PathBuf};

use fp4train::config::RunConfig;
use fp4train::coordinator::multiproc::{run_participant, MpOptions};
use fp4train::coordinator::runstore::{LeaseState, RunStatus, RunStore};
use fp4train::refmodel::{train_host_with, HostRunResult, TrainOptions};

fn tdir(name: &str) -> PathBuf {
    let d = std::env::temp_dir().join("fp4orch").join(name);
    let _ = std::fs::remove_dir_all(&d);
    std::fs::create_dir_all(&d).unwrap();
    d
}

/// Tiny-but-real geometry: 8 steps, checkpoints every 2, stage boundary
/// at step 6 (tail frac 0.25), same corpus scale as the engine's
/// reproducibility test.
fn micro_cfg(root: &Path, tag: &str, workers: usize) -> RunConfig {
    let mut cfg = RunConfig::default();
    cfg.model = "gpt2-s-proxy".into();
    cfg.recipe = "ours".into();
    cfg.steps = 8;
    cfg.workers = workers;
    cfg.eval_every = 8;
    cfg.log_every = 8;
    cfg.checkpoint_every = 2;
    cfg.target_precision_frac = 0.25;
    cfg.data.n_docs = 220;
    cfg.out_dir = root.join(tag).to_str().unwrap().to_string();
    cfg
}

/// Every master-parameter bit of a finished run.
fn param_bits(res: HostRunResult) -> Vec<u32> {
    let mut model = res.model;
    let mut bits = Vec::new();
    for (_, p) in model.params_mut() {
        bits.extend(p.iter().map(|v| v.to_bits()));
    }
    bits
}

fn durable(run_dir: PathBuf) -> TrainOptions {
    TrainOptions { run_dir: Some(run_dir), ..Default::default() }
}

#[test]
fn crash_resume_bit_identical_sweep() {
    let root = tdir("sweep");
    // uninterrupted durable reference
    let ref_res =
        train_host_with(&micro_cfg(&root, "ref", 1), &durable(root.join("ref_run"))).unwrap();
    let ref_losses: Vec<u32> = ref_res.metrics.steps.iter().map(|s| s.loss.to_bits()).collect();
    assert_eq!(ref_losses.len(), 8);
    let ref_bits = param_bits(ref_res);

    // k=1: before the first checkpoint (resume = full replay from init);
    // k=3: between checkpoints, mid-run; k=6: the §3.3 stage boundary
    for k in [1u64, 3, 6] {
        let run_dir = root.join(format!("run_k{k}"));
        let cfg = micro_cfg(&root, &format!("k{k}"), 1);
        let mut opts = durable(run_dir.clone());
        opts.fault_at = Some(k);
        let err = format!("{:#}", train_host_with(&cfg, &opts).unwrap_err());
        assert!(err.contains("injected fault"), "k={k}: {err}");

        // the store recorded the fault (best-effort audit)
        let store = RunStore::open(&run_dir).unwrap();
        assert_eq!(store.status(), RunStatus::Faulted, "k={k}");
        drop(store);

        // resume to completion in a fresh "process"
        let opts = TrainOptions { run_dir: Some(run_dir.clone()), resume: true, ..Default::default() };
        let res = train_host_with(&cfg, &opts).unwrap();

        // every replayed step's loss is byte-identical to the reference
        assert!(!res.metrics.steps.is_empty(), "k={k}");
        for r in &res.metrics.steps {
            assert_eq!(
                r.loss.to_bits(),
                ref_losses[r.step as usize],
                "k={k}: loss diverged at step {}",
                r.step
            );
        }
        // final loss byte-identical (the headline acceptance check)
        assert_eq!(
            res.metrics.steps.last().unwrap().loss.to_bits(),
            *ref_losses.last().unwrap(),
            "k={k}: final loss"
        );
        // and every final master-parameter bit matches
        assert_eq!(param_bits(res), ref_bits, "k={k}: param bits diverged");

        // the run store converged to Complete with all shards done
        let store = RunStore::open(&run_dir).unwrap();
        assert_eq!(store.status(), RunStatus::Complete, "k={k}");
        assert!(store.leases().iter().all(|l| l.state == LeaseState::Done), "k={k}");
        assert_eq!(store.resumes(), 1, "k={k}");
    }
}

#[test]
fn crash_resume_bit_identical_with_sharded_workers() {
    // W=2: per-shard grads merged in ascending-shard order; a crash and
    // resume re-leases both shards and must reproduce the same bits
    let root = tdir("sharded");
    let ref_res =
        train_host_with(&micro_cfg(&root, "ref", 2), &durable(root.join("ref_run"))).unwrap();
    let ref_losses: Vec<u32> = ref_res.metrics.steps.iter().map(|s| s.loss.to_bits()).collect();
    let ref_bits = param_bits(ref_res);

    let run_dir = root.join("chaos_run");
    let cfg = micro_cfg(&root, "chaos", 2);
    let mut opts = durable(run_dir.clone());
    opts.fault_at = Some(3);
    assert!(train_host_with(&cfg, &opts).is_err());
    let opts = TrainOptions { run_dir: Some(run_dir), resume: true, ..Default::default() };
    let res = train_host_with(&cfg, &opts).unwrap();
    for r in &res.metrics.steps {
        assert_eq!(r.loss.to_bits(), ref_losses[r.step as usize], "step {}", r.step);
    }
    assert_eq!(param_bits(res), ref_bits, "sharded param bits diverged");
}

#[test]
fn resume_rejects_drifted_config() {
    let root = tdir("drift");
    let cfg = micro_cfg(&root, "a", 1);
    let run_dir = root.join("run");
    let mut opts = durable(run_dir.clone());
    opts.fault_at = Some(2);
    assert!(train_host_with(&cfg, &opts).is_err());
    // resume with a different seed must fail loudly, not drift silently
    let mut drifted = cfg.clone();
    drifted.seed += 1;
    let opts = TrainOptions { run_dir: Some(run_dir), resume: true, ..Default::default() };
    let err = format!("{:#}", train_host_with(&drifted, &opts).unwrap_err());
    assert!(err.contains("config mismatch"), "{err}");
}

#[test]
fn fresh_run_refuses_existing_run_dir_and_complete_runs_refuse_resume() {
    let root = tdir("refuse");
    let cfg = micro_cfg(&root, "a", 1);
    let run_dir = root.join("run");
    train_host_with(&cfg, &durable(run_dir.clone())).unwrap();
    // same dir without --resume: refuse to clobber
    let err = format!("{:#}", train_host_with(&cfg, &durable(run_dir.clone())).unwrap_err());
    assert!(err.contains("--resume"), "{err}");
    // resume of a complete run: nothing to do, says so
    let opts = TrainOptions { run_dir: Some(run_dir), resume: true, ..Default::default() };
    let err = format!("{:#}", train_host_with(&cfg, &opts).unwrap_err());
    assert!(err.contains("already complete"), "{err}");
}

#[test]
fn truncated_checkpoint_fails_resume_with_path() {
    let root = tdir("truncated");
    let cfg = micro_cfg(&root, "a", 1);
    let run_dir = root.join("run");
    let mut opts = durable(run_dir.clone());
    opts.fault_at = Some(5); // checkpoints exist at steps 2 and 4
    assert!(train_host_with(&cfg, &opts).is_err());
    // corrupt the latest checkpoint the way a torn disk would: cut bytes
    let store = RunStore::open(&run_dir).unwrap();
    let (step, ckpt) = store.latest_checkpoint().unwrap();
    assert_eq!(step, 4);
    drop(store);
    let bytes = std::fs::read(&ckpt).unwrap();
    std::fs::write(&ckpt, &bytes[..bytes.len() / 2]).unwrap();
    let opts = TrainOptions { run_dir: Some(run_dir), resume: true, ..Default::default() };
    let err = format!("{:#}", train_host_with(&cfg, &opts).unwrap_err());
    assert!(
        err.contains(ckpt.file_name().unwrap().to_str().unwrap()),
        "error must name the corrupt file: {err}"
    );
    assert!(
        err.contains("truncated") || err.contains("checksum") || err.contains("decompressing"),
        "error must name the failure mode: {err}"
    );
}

// ---------------------------------------------------------------------------
// Multi-process data parallelism (threads emulating worker processes: each
// participant owns a full model+optimizer replica and rendezvouses purely
// through the run-dir files, exactly like separate `worker` processes)
// ---------------------------------------------------------------------------

fn mp_opts(dir: &Path, id: &str, coordinator_only: bool, fault_at: Option<u64>) -> MpOptions {
    MpOptions {
        run_dir: dir.to_path_buf(),
        worker_id: id.to_string(),
        coordinator_only,
        train: TrainOptions {
            heartbeat_ms: 100,
            lease_timeout_ms: 400,
            fault_at,
            ..Default::default()
        },
    }
}

/// Spawn a participant thread; returns its join handle.
fn spawn_participant(
    cfg: &RunConfig,
    dir: &Path,
    id: &str,
    coordinator_only: bool,
    fault_at: Option<u64>,
) -> std::thread::JoinHandle<anyhow::Result<HostRunResult>> {
    let cfg = cfg.clone();
    let o = mp_opts(dir, id, coordinator_only, fault_at);
    std::thread::spawn(move || run_participant(&cfg, &o))
}

/// Block until the store exists, so the dedicated coordinator — not a
/// racing worker — fixes the run's coordinator mode at creation.
fn wait_for_store(dir: &Path) {
    let deadline = std::time::Instant::now() + std::time::Duration::from_secs(30);
    while !dir.join("run.json").exists() {
        assert!(std::time::Instant::now() < deadline, "store never appeared in {dir:?}");
        std::thread::sleep(std::time::Duration::from_millis(5));
    }
}

#[test]
fn multiprocess_external_run_matches_in_process_bits() {
    let root = tdir("mp_clean");
    // uninterrupted in-process reference at the same shard count
    let ref_res = train_host_with(&micro_cfg(&root, "ref", 3), &TrainOptions::default()).unwrap();
    let ref_losses: Vec<u32> = ref_res.metrics.steps.iter().map(|s| s.loss.to_bits()).collect();
    let ref_bits = param_bits(ref_res);

    let cfg = micro_cfg(&root, "mp", 3);
    let dir = root.join("mp_run");
    let coord = spawn_participant(&cfg, &dir, "coord", true, None);
    wait_for_store(&dir);
    let workers: Vec<_> = (0..3)
        .map(|i| spawn_participant(&cfg, &dir, &format!("w{i}"), false, None))
        .collect();

    let cres = coord.join().unwrap().unwrap();
    // the coordinator is at the frontier for the whole run: full history,
    // every per-step loss bit identical to the in-process engine
    assert_eq!(cres.metrics.steps.len(), 8);
    for r in &cres.metrics.steps {
        assert_eq!(r.loss.to_bits(), ref_losses[r.step as usize], "loss bits at step {}", r.step);
    }
    assert_eq!(param_bits(cres), ref_bits, "coordinator param bits diverged");
    // every worker replica converged to the identical bytes (a slow
    // starter may have caught up via checkpoint restore — same bits)
    for (i, w) in workers.into_iter().enumerate() {
        let res = w.join().unwrap().unwrap();
        for r in &res.metrics.steps {
            assert_eq!(r.loss.to_bits(), ref_losses[r.step as usize], "w{i} loss at {}", r.step);
        }
        assert_eq!(param_bits(res), ref_bits, "w{i} param bits diverged");
    }

    let store = RunStore::open(&dir).unwrap();
    assert_eq!(store.status(), RunStatus::Complete);
    assert!(store.leases().iter().all(|l| l.state == LeaseState::Done));
    assert!(store.meta().external_coordinator);
}

#[test]
fn multiprocess_kill9_failover_and_relaunch_bit_identical() {
    let root = tdir("mp_chaos");
    let ref_res = train_host_with(&micro_cfg(&root, "ref", 3), &TrainOptions::default()).unwrap();
    let ref_losses: Vec<u32> = ref_res.metrics.steps.iter().map(|s| s.loss.to_bits()).collect();
    let ref_bits = param_bits(ref_res);

    let cfg = micro_cfg(&root, "mp", 3);
    let dir = root.join("mp_run");
    let coord = spawn_participant(&cfg, &dir, "coord", true, None);
    wait_for_store(&dir);
    // the victim starts first and we wait until it holds a lease, so it
    // deterministically dies owning at least one shard before step 3 —
    // the kill -9 analog (nothing is released; only lease expiry frees
    // its shards)
    let victim = spawn_participant(&cfg, &dir, "victim", false, Some(3));
    let deadline = std::time::Instant::now() + std::time::Duration::from_secs(60);
    loop {
        assert!(std::time::Instant::now() < deadline, "victim never claimed a shard");
        let held = RunStore::open(&dir)
            .map(|s| {
                s.leases()
                    .iter()
                    .any(|l| l.state == LeaseState::Leased && l.worker == "victim")
            })
            .unwrap_or(false);
        if held {
            break;
        }
        std::thread::sleep(std::time::Duration::from_millis(5));
    }
    let survivors: Vec<_> = (0..2)
        .map(|i| spawn_participant(&cfg, &dir, &format!("w{i}"), false, None))
        .collect();

    let err = format!("{:#}", victim.join().unwrap().unwrap_err());
    assert!(err.contains("injected fault"), "{err}");
    // relaunch: a fresh worker attaches mid-run, catches up from the
    // latest checkpoint + published exchanges, and helps finish
    let relaunched = spawn_participant(&cfg, &dir, "relaunch", false, None);

    let cres = coord.join().unwrap().unwrap();
    assert_eq!(cres.metrics.steps.len(), 8);
    for r in &cres.metrics.steps {
        assert_eq!(r.loss.to_bits(), ref_losses[r.step as usize], "loss bits at step {}", r.step);
    }
    assert_eq!(param_bits(cres), ref_bits, "coordinator param bits diverged after failover");
    for (i, w) in survivors.into_iter().enumerate() {
        let res = w.join().unwrap().unwrap();
        assert_eq!(param_bits(res), ref_bits, "survivor w{i} param bits diverged");
    }
    let res = relaunched.join().unwrap().unwrap();
    assert_eq!(param_bits(res), ref_bits, "relaunched worker param bits diverged");

    // the store recorded the death and the takeover: the victim's shard
    // was expired and re-leased at a bumped fence, and the run sealed
    let store = RunStore::open(&dir).unwrap();
    assert_eq!(store.status(), RunStatus::Complete);
    assert!(store.leases().iter().all(|l| l.state == LeaseState::Done));
    assert!(
        store.leases().iter().any(|l| l.fence > 1),
        "some shard must have been re-leased after the kill: {:?}",
        store.leases()
    );
    let events: Vec<String> = store
        .read_journal()
        .unwrap()
        .iter()
        .map(|j| j.get("event").and_then(|e| e.as_str()).unwrap_or("?").to_string())
        .collect();
    assert!(events.iter().any(|e| e == "expire"), "journal must record the expiry: {events:?}");
    assert!(events.iter().any(|e| e == "exchange"), "{events:?}");
}

#[test]
fn multiprocess_elected_coordinator_matches_in_process_bits() {
    let root = tdir("mp_elected");
    let ref_res = train_host_with(&micro_cfg(&root, "ref", 2), &TrainOptions::default()).unwrap();
    let ref_losses: Vec<u32> = ref_res.metrics.steps.iter().map(|s| s.loss.to_bits()).collect();
    let ref_bits = param_bits(ref_res);

    // no dedicated coordinator: the first worker creates the store in
    // elected mode and the current holder of shard 0 merges
    let cfg = micro_cfg(&root, "mp", 2);
    let dir = root.join("mp_run");
    let w0 = spawn_participant(&cfg, &dir, "w0", false, None);
    wait_for_store(&dir);
    let w1 = spawn_participant(&cfg, &dir, "w1", false, None);

    for (name, h) in [("w0", w0), ("w1", w1)] {
        let res = h.join().unwrap().unwrap();
        for r in &res.metrics.steps {
            assert_eq!(r.loss.to_bits(), ref_losses[r.step as usize], "{name} loss at {}", r.step);
        }
        assert_eq!(param_bits(res), ref_bits, "{name} param bits diverged");
    }
    let store = RunStore::open(&dir).unwrap();
    assert_eq!(store.status(), RunStatus::Complete);
    assert!(!store.meta().external_coordinator);
    assert!(store.leases().iter().all(|l| l.state == LeaseState::Done));
}

#[test]
fn fault_env_parses_like_pallas_threads() {
    // no other test in this binary reads PALLAS_FAULT from the env (the
    // sweep drives TrainOptions::fault_at directly), so this is race-free
    use fp4train::refmodel::engine::fault_from_env;
    std::env::remove_var("PALLAS_FAULT");
    assert_eq!(fault_from_env(), None);
    std::env::set_var("PALLAS_FAULT", "23");
    assert_eq!(fault_from_env(), Some(23));
    std::env::set_var("PALLAS_FAULT", " 7 ");
    assert_eq!(fault_from_env(), Some(7));
    std::env::set_var("PALLAS_FAULT", "not-a-step");
    assert_eq!(fault_from_env(), None);
    std::env::remove_var("PALLAS_FAULT");
}
