//! Durable orchestration end-to-end: crash → resume is bit-identical to an
//! uninterrupted run (the ROADMAP's headline verify), the run store's
//! lease machinery survives process death, and corrupted checkpoints fail
//! loudly with the offending path.
//!
//! The fault sweep drives `TrainOptions::fault_at` (the in-process form of
//! `PALLAS_FAULT`) at three structurally different steps: before the first
//! checkpoint (full replay from init), mid-run between checkpoints, and
//! exactly at the §3.3 stage boundary where the recipe swaps to the
//! target.  Every surviving loss bit and every final master-parameter bit
//! must match the uninterrupted reference.
//!
//! The sentinel suite at the bottom drives `TrainOptions::numfaults` (the
//! in-process form of `PALLAS_NUMFAULT`) and pins the training-health
//! contract: a run that hits an injected NaN or spike, rolls back, and
//! skips the poisoned window ends bit-identical to a clean run on the
//! post-skip data order — single-process and multi-process.

use std::path::{Path, PathBuf};

use fp4train::config::RunConfig;
use fp4train::coordinator::multiproc::{run_participant, MpOptions};
use fp4train::coordinator::runstore::{LeaseState, RunStatus, RunStore};
use fp4train::refmodel::{train_host_with, HostRunResult, TrainOptions};

fn tdir(name: &str) -> PathBuf {
    let d = std::env::temp_dir().join("fp4orch").join(name);
    let _ = std::fs::remove_dir_all(&d);
    std::fs::create_dir_all(&d).unwrap();
    d
}

/// Tiny-but-real geometry: 8 steps, checkpoints every 2, stage boundary
/// at step 6 (tail frac 0.25), same corpus scale as the engine's
/// reproducibility test.
fn micro_cfg(root: &Path, tag: &str, workers: usize) -> RunConfig {
    let mut cfg = RunConfig::default();
    cfg.model = "gpt2-s-proxy".into();
    cfg.recipe = "ours".into();
    cfg.steps = 8;
    cfg.workers = workers;
    cfg.eval_every = 8;
    cfg.log_every = 8;
    cfg.checkpoint_every = 2;
    cfg.target_precision_frac = 0.25;
    cfg.data.n_docs = 220;
    cfg.out_dir = root.join(tag).to_str().unwrap().to_string();
    cfg
}

/// Every master-parameter bit of a finished run.
fn param_bits(res: HostRunResult) -> Vec<u32> {
    let mut model = res.model;
    let mut bits = Vec::new();
    for (_, p) in model.params_mut() {
        bits.extend(p.iter().map(|v| v.to_bits()));
    }
    bits
}

fn durable(run_dir: PathBuf) -> TrainOptions {
    TrainOptions { run_dir: Some(run_dir), ..Default::default() }
}

#[test]
fn crash_resume_bit_identical_sweep() {
    let root = tdir("sweep");
    // uninterrupted durable reference
    let ref_res =
        train_host_with(&micro_cfg(&root, "ref", 1), &durable(root.join("ref_run"))).unwrap();
    let ref_losses: Vec<u32> = ref_res.metrics.steps.iter().map(|s| s.loss.to_bits()).collect();
    assert_eq!(ref_losses.len(), 8);
    let ref_bits = param_bits(ref_res);

    // k=1: before the first checkpoint (resume = full replay from init);
    // k=3: between checkpoints, mid-run; k=6: the §3.3 stage boundary
    for k in [1u64, 3, 6] {
        let run_dir = root.join(format!("run_k{k}"));
        let cfg = micro_cfg(&root, &format!("k{k}"), 1);
        let mut opts = durable(run_dir.clone());
        opts.fault_at = Some(k);
        let err = format!("{:#}", train_host_with(&cfg, &opts).unwrap_err());
        assert!(err.contains("injected fault"), "k={k}: {err}");

        // the store recorded the fault (best-effort audit)
        let store = RunStore::open(&run_dir).unwrap();
        assert_eq!(store.status(), RunStatus::Faulted, "k={k}");
        drop(store);

        // resume to completion in a fresh "process"
        let opts = TrainOptions { run_dir: Some(run_dir.clone()), resume: true, ..Default::default() };
        let res = train_host_with(&cfg, &opts).unwrap();

        // every replayed step's loss is byte-identical to the reference
        assert!(!res.metrics.steps.is_empty(), "k={k}");
        for r in &res.metrics.steps {
            assert_eq!(
                r.loss.to_bits(),
                ref_losses[r.step as usize],
                "k={k}: loss diverged at step {}",
                r.step
            );
        }
        // final loss byte-identical (the headline acceptance check)
        assert_eq!(
            res.metrics.steps.last().unwrap().loss.to_bits(),
            *ref_losses.last().unwrap(),
            "k={k}: final loss"
        );
        // and every final master-parameter bit matches
        assert_eq!(param_bits(res), ref_bits, "k={k}: param bits diverged");

        // the run store converged to Complete with all shards done
        let store = RunStore::open(&run_dir).unwrap();
        assert_eq!(store.status(), RunStatus::Complete, "k={k}");
        assert!(store.leases().iter().all(|l| l.state == LeaseState::Done), "k={k}");
        assert_eq!(store.resumes(), 1, "k={k}");
    }
}

#[test]
fn crash_resume_bit_identical_with_sharded_workers() {
    // W=2: per-shard grads merged in ascending-shard order; a crash and
    // resume re-leases both shards and must reproduce the same bits
    let root = tdir("sharded");
    let ref_res =
        train_host_with(&micro_cfg(&root, "ref", 2), &durable(root.join("ref_run"))).unwrap();
    let ref_losses: Vec<u32> = ref_res.metrics.steps.iter().map(|s| s.loss.to_bits()).collect();
    let ref_bits = param_bits(ref_res);

    let run_dir = root.join("chaos_run");
    let cfg = micro_cfg(&root, "chaos", 2);
    let mut opts = durable(run_dir.clone());
    opts.fault_at = Some(3);
    assert!(train_host_with(&cfg, &opts).is_err());
    let opts = TrainOptions { run_dir: Some(run_dir), resume: true, ..Default::default() };
    let res = train_host_with(&cfg, &opts).unwrap();
    for r in &res.metrics.steps {
        assert_eq!(r.loss.to_bits(), ref_losses[r.step as usize], "step {}", r.step);
    }
    assert_eq!(param_bits(res), ref_bits, "sharded param bits diverged");
}

#[test]
fn resume_rejects_drifted_config() {
    let root = tdir("drift");
    let cfg = micro_cfg(&root, "a", 1);
    let run_dir = root.join("run");
    let mut opts = durable(run_dir.clone());
    opts.fault_at = Some(2);
    assert!(train_host_with(&cfg, &opts).is_err());
    // resume with a different seed must fail loudly, not drift silently
    let mut drifted = cfg.clone();
    drifted.seed += 1;
    let opts = TrainOptions { run_dir: Some(run_dir), resume: true, ..Default::default() };
    let err = format!("{:#}", train_host_with(&drifted, &opts).unwrap_err());
    assert!(err.contains("config mismatch"), "{err}");
}

#[test]
fn fresh_run_refuses_existing_run_dir_and_complete_runs_refuse_resume() {
    let root = tdir("refuse");
    let cfg = micro_cfg(&root, "a", 1);
    let run_dir = root.join("run");
    train_host_with(&cfg, &durable(run_dir.clone())).unwrap();
    // same dir without --resume: refuse to clobber
    let err = format!("{:#}", train_host_with(&cfg, &durable(run_dir.clone())).unwrap_err());
    assert!(err.contains("--resume"), "{err}");
    // resume of a complete run: nothing to do, says so
    let opts = TrainOptions { run_dir: Some(run_dir), resume: true, ..Default::default() };
    let err = format!("{:#}", train_host_with(&cfg, &opts).unwrap_err());
    assert!(err.contains("already complete"), "{err}");
}

#[test]
fn truncated_checkpoint_fails_resume_with_path() {
    let root = tdir("truncated");
    let cfg = micro_cfg(&root, "a", 1);
    let run_dir = root.join("run");
    let mut opts = durable(run_dir.clone());
    opts.fault_at = Some(5); // checkpoints exist at steps 2 and 4
    assert!(train_host_with(&cfg, &opts).is_err());
    // corrupt the latest checkpoint the way a torn disk would: cut bytes
    let store = RunStore::open(&run_dir).unwrap();
    let (step, ckpt) = store.latest_checkpoint().unwrap();
    assert_eq!(step, 4);
    drop(store);
    let bytes = std::fs::read(&ckpt).unwrap();
    std::fs::write(&ckpt, &bytes[..bytes.len() / 2]).unwrap();
    let opts = TrainOptions { run_dir: Some(run_dir), resume: true, ..Default::default() };
    let err = format!("{:#}", train_host_with(&cfg, &opts).unwrap_err());
    assert!(
        err.contains(ckpt.file_name().unwrap().to_str().unwrap()),
        "error must name the corrupt file: {err}"
    );
    assert!(
        err.contains("truncated") || err.contains("checksum") || err.contains("decompressing"),
        "error must name the failure mode: {err}"
    );
}

// ---------------------------------------------------------------------------
// Multi-process data parallelism (threads emulating worker processes: each
// participant owns a full model+optimizer replica and rendezvouses purely
// through the run-dir files, exactly like separate `worker` processes)
// ---------------------------------------------------------------------------

fn mp_opts(dir: &Path, id: &str, coordinator_only: bool, fault_at: Option<u64>) -> MpOptions {
    MpOptions {
        run_dir: dir.to_path_buf(),
        worker_id: id.to_string(),
        coordinator_only,
        train: TrainOptions {
            heartbeat_ms: 100,
            lease_timeout_ms: 400,
            fault_at,
            ..Default::default()
        },
    }
}

/// Spawn a participant thread; returns its join handle.
fn spawn_participant(
    cfg: &RunConfig,
    dir: &Path,
    id: &str,
    coordinator_only: bool,
    fault_at: Option<u64>,
) -> std::thread::JoinHandle<anyhow::Result<HostRunResult>> {
    let cfg = cfg.clone();
    let o = mp_opts(dir, id, coordinator_only, fault_at);
    std::thread::spawn(move || run_participant(&cfg, &o))
}

/// Block until the store exists, so the dedicated coordinator — not a
/// racing worker — fixes the run's coordinator mode at creation.
fn wait_for_store(dir: &Path) {
    let deadline = std::time::Instant::now() + std::time::Duration::from_secs(30);
    while !dir.join("run.json").exists() {
        assert!(std::time::Instant::now() < deadline, "store never appeared in {dir:?}");
        std::thread::sleep(std::time::Duration::from_millis(5));
    }
}

#[test]
fn multiprocess_external_run_matches_in_process_bits() {
    let root = tdir("mp_clean");
    // uninterrupted in-process reference at the same shard count
    let ref_res = train_host_with(&micro_cfg(&root, "ref", 3), &TrainOptions::default()).unwrap();
    let ref_losses: Vec<u32> = ref_res.metrics.steps.iter().map(|s| s.loss.to_bits()).collect();
    let ref_bits = param_bits(ref_res);

    let cfg = micro_cfg(&root, "mp", 3);
    let dir = root.join("mp_run");
    let coord = spawn_participant(&cfg, &dir, "coord", true, None);
    wait_for_store(&dir);
    let workers: Vec<_> = (0..3)
        .map(|i| spawn_participant(&cfg, &dir, &format!("w{i}"), false, None))
        .collect();

    let cres = coord.join().unwrap().unwrap();
    // the coordinator is at the frontier for the whole run: full history,
    // every per-step loss bit identical to the in-process engine
    assert_eq!(cres.metrics.steps.len(), 8);
    for r in &cres.metrics.steps {
        assert_eq!(r.loss.to_bits(), ref_losses[r.step as usize], "loss bits at step {}", r.step);
    }
    assert_eq!(param_bits(cres), ref_bits, "coordinator param bits diverged");
    // every worker replica converged to the identical bytes (a slow
    // starter may have caught up via checkpoint restore — same bits)
    for (i, w) in workers.into_iter().enumerate() {
        let res = w.join().unwrap().unwrap();
        for r in &res.metrics.steps {
            assert_eq!(r.loss.to_bits(), ref_losses[r.step as usize], "w{i} loss at {}", r.step);
        }
        assert_eq!(param_bits(res), ref_bits, "w{i} param bits diverged");
    }

    let store = RunStore::open(&dir).unwrap();
    assert_eq!(store.status(), RunStatus::Complete);
    assert!(store.leases().iter().all(|l| l.state == LeaseState::Done));
    assert!(store.meta().external_coordinator);
}

#[test]
fn multiprocess_kill9_failover_and_relaunch_bit_identical() {
    let root = tdir("mp_chaos");
    let ref_res = train_host_with(&micro_cfg(&root, "ref", 3), &TrainOptions::default()).unwrap();
    let ref_losses: Vec<u32> = ref_res.metrics.steps.iter().map(|s| s.loss.to_bits()).collect();
    let ref_bits = param_bits(ref_res);

    let cfg = micro_cfg(&root, "mp", 3);
    let dir = root.join("mp_run");
    let coord = spawn_participant(&cfg, &dir, "coord", true, None);
    wait_for_store(&dir);
    // the victim starts first and we wait until it holds a lease, so it
    // deterministically dies owning at least one shard before step 3 —
    // the kill -9 analog (nothing is released; only lease expiry frees
    // its shards)
    let victim = spawn_participant(&cfg, &dir, "victim", false, Some(3));
    let deadline = std::time::Instant::now() + std::time::Duration::from_secs(60);
    loop {
        assert!(std::time::Instant::now() < deadline, "victim never claimed a shard");
        let held = RunStore::open(&dir)
            .map(|s| {
                s.leases()
                    .iter()
                    .any(|l| l.state == LeaseState::Leased && l.worker == "victim")
            })
            .unwrap_or(false);
        if held {
            break;
        }
        std::thread::sleep(std::time::Duration::from_millis(5));
    }
    let survivors: Vec<_> = (0..2)
        .map(|i| spawn_participant(&cfg, &dir, &format!("w{i}"), false, None))
        .collect();

    let err = format!("{:#}", victim.join().unwrap().unwrap_err());
    assert!(err.contains("injected fault"), "{err}");
    // relaunch: a fresh worker attaches mid-run, catches up from the
    // latest checkpoint + published exchanges, and helps finish
    let relaunched = spawn_participant(&cfg, &dir, "relaunch", false, None);

    let cres = coord.join().unwrap().unwrap();
    assert_eq!(cres.metrics.steps.len(), 8);
    for r in &cres.metrics.steps {
        assert_eq!(r.loss.to_bits(), ref_losses[r.step as usize], "loss bits at step {}", r.step);
    }
    assert_eq!(param_bits(cres), ref_bits, "coordinator param bits diverged after failover");
    for (i, w) in survivors.into_iter().enumerate() {
        let res = w.join().unwrap().unwrap();
        assert_eq!(param_bits(res), ref_bits, "survivor w{i} param bits diverged");
    }
    let res = relaunched.join().unwrap().unwrap();
    assert_eq!(param_bits(res), ref_bits, "relaunched worker param bits diverged");

    // the store recorded the death and the takeover: the victim's shard
    // was expired and re-leased at a bumped fence, and the run sealed
    let store = RunStore::open(&dir).unwrap();
    assert_eq!(store.status(), RunStatus::Complete);
    assert!(store.leases().iter().all(|l| l.state == LeaseState::Done));
    assert!(
        store.leases().iter().any(|l| l.fence > 1),
        "some shard must have been re-leased after the kill: {:?}",
        store.leases()
    );
    let events: Vec<String> = store
        .read_journal()
        .unwrap()
        .iter()
        .map(|j| j.get("event").and_then(|e| e.as_str()).unwrap_or("?").to_string())
        .collect();
    assert!(events.iter().any(|e| e == "expire"), "journal must record the expiry: {events:?}");
    assert!(events.iter().any(|e| e == "exchange"), "{events:?}");
}

#[test]
fn multiprocess_elected_coordinator_matches_in_process_bits() {
    let root = tdir("mp_elected");
    let ref_res = train_host_with(&micro_cfg(&root, "ref", 2), &TrainOptions::default()).unwrap();
    let ref_losses: Vec<u32> = ref_res.metrics.steps.iter().map(|s| s.loss.to_bits()).collect();
    let ref_bits = param_bits(ref_res);

    // no dedicated coordinator: the first worker creates the store in
    // elected mode and the current holder of shard 0 merges
    let cfg = micro_cfg(&root, "mp", 2);
    let dir = root.join("mp_run");
    let w0 = spawn_participant(&cfg, &dir, "w0", false, None);
    wait_for_store(&dir);
    let w1 = spawn_participant(&cfg, &dir, "w1", false, None);

    for (name, h) in [("w0", w0), ("w1", w1)] {
        let res = h.join().unwrap().unwrap();
        for r in &res.metrics.steps {
            assert_eq!(r.loss.to_bits(), ref_losses[r.step as usize], "{name} loss at {}", r.step);
        }
        assert_eq!(param_bits(res), ref_bits, "{name} param bits diverged");
    }
    let store = RunStore::open(&dir).unwrap();
    assert_eq!(store.status(), RunStatus::Complete);
    assert!(!store.meta().external_coordinator);
    assert!(store.leases().iter().all(|l| l.state == LeaseState::Done));
}

// ---------------------------------------------------------------------------
// Training-health sentinel: deterministic numeric-fault injection, rollback
// to the latest durable checkpoint, batch-window skip, and precision
// fallback.  The headline invariant: a run that hits an injected fault and
// recovers ends **bit-identical** to an uninterrupted run on the post-skip
// data order — single-process and multi-process.
// ---------------------------------------------------------------------------

use fp4train::coordinator::metrics::Health;
use fp4train::coordinator::sentinel::{NumFault, NumFaultKind};

fn journal_events(run_dir: &Path) -> Vec<String> {
    RunStore::open(run_dir)
        .unwrap()
        .read_journal()
        .unwrap()
        .iter()
        .map(|j| j.get("event").and_then(|e| e.as_str()).unwrap_or("?").to_string())
        .collect()
}

/// Clean durable reference run with the sentinel disabled and the given
/// data indices pre-skipped: the ground truth a recovered run must match.
fn clean_reference(cfg: &RunConfig, run_dir: PathBuf, skips: Vec<u64>) -> (Vec<u32>, Vec<u32>) {
    let mut opts = durable(run_dir);
    opts.skips = skips;
    opts.sentinel_off = true;
    let res = train_host_with(cfg, &opts).unwrap();
    let losses = res.metrics.steps.iter().map(|s| s.loss.to_bits()).collect();
    (losses, param_bits(res))
}

#[test]
fn injected_nan_recovers_bit_identical_to_clean_post_skip_run() {
    let root = tdir("sentinel_nan");
    // fault at data index 5 → rollback to the step-4 checkpoint, skip 5;
    // the clean reference runs on data order 0,1,2,3,4,6,7,8
    let (ref_losses, ref_bits) =
        clean_reference(&micro_cfg(&root, "ref", 1), root.join("ref_run"), vec![5]);

    let run_dir = root.join("run");
    let mut opts = durable(run_dir.clone());
    opts.numfaults = vec![NumFault { at: 5, kind: NumFaultKind::Nan }];
    let res = train_host_with(&micro_cfg(&root, "nan", 1), &opts).unwrap();

    assert_eq!(res.metrics.steps.len(), 8);
    for r in &res.metrics.steps {
        assert_eq!(r.loss.to_bits(), ref_losses[r.step as usize], "loss diverged at {}", r.step);
        assert_eq!(r.health, Health::Ok, "no escalation → every row stays ok (step {})", r.step);
    }
    assert_eq!(param_bits(res), ref_bits, "recovered params diverged from clean post-skip run");

    let store = RunStore::open(&run_dir).unwrap();
    assert_eq!(store.status(), RunStatus::Complete);
    assert_eq!(store.skips().to_vec(), vec![5u64]);
    let ivs = store.interventions();
    assert_eq!(ivs.len(), 1, "exactly one intervention: {ivs:?}");
    assert_eq!(ivs[0].at_step, 5);
    assert_eq!(ivs[0].data_step, 5);
    assert_eq!(ivs[0].kind, "nonfinite:loss");
    assert_eq!(ivs[0].rollback_to, 4, "latest checkpoint before the fault is step 4");
    assert_eq!(ivs[0].retry, 0);
    assert!(ivs[0].escalation.is_none(), "first strike must not escalate");
    drop(store);
    assert!(
        journal_events(&run_dir).iter().any(|e| e == "intervention"),
        "journal must carry the intervention audit line"
    );
}

#[test]
fn injected_spike_recovers_bit_identical_to_clean_post_skip_run() {
    let root = tdir("sentinel_spike");
    let (ref_losses, ref_bits) =
        clean_reference(&micro_cfg(&root, "ref", 1), root.join("ref_run"), vec![5]);

    let run_dir = root.join("run");
    let mut opts = durable(run_dir.clone());
    opts.numfaults = vec![NumFault { at: 5, kind: NumFaultKind::Spike }];
    // short warmup so the z-score is armed by step 5; the threshold sits
    // far above healthy jitter and far below a ×1e4 gradient blow-up
    opts.spike_window = 4;
    opts.spike_zscore = 50.0;
    let res = train_host_with(&micro_cfg(&root, "spike", 1), &opts).unwrap();

    assert_eq!(res.metrics.steps.len(), 8);
    for r in &res.metrics.steps {
        assert_eq!(r.loss.to_bits(), ref_losses[r.step as usize], "loss diverged at {}", r.step);
    }
    assert_eq!(param_bits(res), ref_bits, "recovered params diverged from clean post-skip run");

    let store = RunStore::open(&run_dir).unwrap();
    let ivs = store.interventions();
    assert_eq!(ivs.len(), 1, "exactly one intervention: {ivs:?}");
    assert!(ivs[0].kind.starts_with("spike:"), "verdict must be a spike: {}", ivs[0].kind);
    assert_eq!(ivs[0].data_step, 5);
}

#[test]
fn rollback_across_stage_boundary_reapplies_recipe() {
    // checkpoint cadence 4 puts the latest checkpoint (step 4) inside
    // stage 1 while the fault fires at step 6 — the first stage-2 step
    // (§3.3 boundary at 8 × (1 - 0.25) = 6).  The replay must re-apply
    // the base recipe for steps 4-5 and swap back to the target at 6.
    let root = tdir("sentinel_stage");
    let mut ref_cfg = micro_cfg(&root, "ref", 1);
    ref_cfg.checkpoint_every = 4;
    let (ref_losses, ref_bits) = clean_reference(&ref_cfg, root.join("ref_run"), vec![6]);

    let mut cfg = micro_cfg(&root, "nan", 1);
    cfg.checkpoint_every = 4;
    let run_dir = root.join("run");
    let mut opts = durable(run_dir.clone());
    opts.numfaults = vec![NumFault { at: 6, kind: NumFaultKind::Nan }];
    let res = train_host_with(&cfg, &opts).unwrap();

    assert_eq!(res.metrics.steps.len(), 8);
    for r in &res.metrics.steps {
        assert_eq!(r.loss.to_bits(), ref_losses[r.step as usize], "loss diverged at {}", r.step);
    }
    assert_eq!(param_bits(res), ref_bits, "stage-boundary rollback diverged");

    let store = RunStore::open(&run_dir).unwrap();
    let ivs = store.interventions();
    assert_eq!(ivs.len(), 1);
    assert_eq!(ivs[0].at_step, 6);
    assert_eq!(ivs[0].rollback_to, 4, "must roll back into stage 1");
}

#[test]
fn repeated_faults_escalate_to_precision_fallback_and_complete() {
    // retries=0: the very first verdict escalates — implicated linears run
    // demoted (FP4 → FP8) for `fallback_cooldown` steps, flagged in the
    // health column, and the run still completes.
    let root = tdir("sentinel_esc");
    let run_dir = root.join("run");
    let mut opts = durable(run_dir.clone());
    opts.numfaults = vec![NumFault { at: 5, kind: NumFaultKind::Nan }];
    opts.rollback_retries = Some(0);
    opts.fallback_cooldown = 2;
    let res = train_host_with(&micro_cfg(&root, "esc", 1), &opts).unwrap();

    assert_eq!(res.metrics.steps.len(), 8);
    for r in &res.metrics.steps {
        let want = if (5..7).contains(&r.step) { Health::Fallback } else { Health::Ok };
        assert_eq!(r.health, want, "health column wrong at step {}", r.step);
    }

    let store = RunStore::open(&run_dir).unwrap();
    assert_eq!(store.status(), RunStatus::Complete);
    let ivs = store.interventions();
    assert_eq!(ivs.len(), 1);
    let esc = ivs[0].escalation.as_ref().expect("retries=0 must escalate immediately");
    assert!(!esc.linears.is_empty(), "escalation must implicate at least one linear");
    assert_eq!(esc.until_step, 7, "at_step 5 + cooldown 2");
}

#[test]
fn sentinel_on_healthy_run_matches_sentinel_off_byte_for_byte() {
    // a healthy run must be untouched by the watching sentinel: every
    // steps.csv column except wall-clock, and every final parameter bit
    let root = tdir("sentinel_ab");
    let on_dir = root.join("on_run");
    let on = train_host_with(&micro_cfg(&root, "on", 1), &durable(on_dir.clone())).unwrap();
    let mut off_opts = durable(root.join("off_run"));
    off_opts.sentinel_off = true;
    let off = train_host_with(&micro_cfg(&root, "off", 1), &off_opts).unwrap();

    assert_eq!(on.metrics.steps.len(), off.metrics.steps.len());
    for (a, b) in on.metrics.steps.iter().zip(off.metrics.steps.iter()) {
        assert_eq!(a.step, b.step);
        assert_eq!(a.loss.to_bits(), b.loss.to_bits(), "loss at {}", a.step);
        assert_eq!(a.grad_norm.to_bits(), b.grad_norm.to_bits(), "grad_norm at {}", a.step);
        assert_eq!(a.stage, b.stage, "stage at {}", a.step);
        assert_eq!(a.health, b.health, "health at {}", a.step);
    }
    assert_eq!(param_bits(on), param_bits(off), "sentinel-on params diverged from sentinel-off");

    let store = RunStore::open(&on_dir).unwrap();
    assert!(store.interventions().is_empty(), "healthy run must record no interventions");
}

#[test]
fn multiprocess_injected_nan_recovers_bit_identical() {
    let root = tdir("mp_sentinel");
    // in-process ephemeral reference at the same shard count on the
    // post-skip data order (no store → sentinel off by construction)
    let mut ref_opts = TrainOptions::default();
    ref_opts.skips = vec![5];
    let ref_res = train_host_with(&micro_cfg(&root, "ref", 3), &ref_opts).unwrap();
    let ref_losses: Vec<u32> = ref_res.metrics.steps.iter().map(|s| s.loss.to_bits()).collect();
    let ref_bits = param_bits(ref_res);

    let cfg = micro_cfg(&root, "mp", 3);
    let dir = root.join("mp_run");
    let train = TrainOptions {
        heartbeat_ms: 100,
        lease_timeout_ms: 400,
        numfaults: vec![NumFault { at: 5, kind: NumFaultKind::Nan }],
        ..Default::default()
    };
    let spawn = |id: &str, coordinator_only: bool| {
        let cfg = cfg.clone();
        let o = MpOptions {
            run_dir: dir.clone(),
            worker_id: id.to_string(),
            coordinator_only,
            train: train.clone(),
        };
        std::thread::spawn(move || run_participant(&cfg, &o))
    };
    let coord = spawn("coord", true);
    wait_for_store(&dir);
    let workers: Vec<_> = (0..3).map(|i| spawn(&format!("w{i}"), false)).collect();

    let cres = coord.join().unwrap().unwrap();
    assert_eq!(cres.metrics.steps.len(), 8);
    for r in &cres.metrics.steps {
        assert_eq!(r.loss.to_bits(), ref_losses[r.step as usize], "loss bits at step {}", r.step);
    }
    assert_eq!(param_bits(cres), ref_bits, "coordinator param bits diverged after recovery");
    for (i, w) in workers.into_iter().enumerate() {
        let res = w.join().unwrap().unwrap();
        assert_eq!(param_bits(res), ref_bits, "w{i} param bits diverged after recovery");
    }

    let store = RunStore::open(&dir).unwrap();
    assert_eq!(store.status(), RunStatus::Complete);
    assert_eq!(store.skips().to_vec(), vec![5u64]);
    let ivs = store.interventions();
    assert_eq!(ivs.len(), 1, "exactly one intervention: {ivs:?}");
    assert_eq!(ivs[0].at_step, 5);
    assert_eq!(ivs[0].kind, "nonfinite:loss");
    drop(store);
    assert!(journal_events(&dir).iter().any(|e| e == "intervention"));
}

#[test]
fn numfault_env_parses_like_pallas_fault() {
    // sole reader of PALLAS_NUMFAULT in this binary (the recovery tests
    // drive TrainOptions::numfaults directly), so this is race-free
    use fp4train::coordinator::sentinel::numfaults_from_env;
    std::env::remove_var("PALLAS_NUMFAULT");
    assert!(numfaults_from_env().is_empty());
    std::env::set_var("PALLAS_NUMFAULT", "5:nan");
    assert_eq!(numfaults_from_env(), vec![NumFault { at: 5, kind: NumFaultKind::Nan }]);
    std::env::set_var("PALLAS_NUMFAULT", " 3:spike , 9:nan ");
    assert_eq!(
        numfaults_from_env(),
        vec![
            NumFault { at: 3, kind: NumFaultKind::Spike },
            NumFault { at: 9, kind: NumFaultKind::Nan }
        ]
    );
    std::env::set_var("PALLAS_NUMFAULT", "7:meteor");
    assert!(numfaults_from_env().is_empty(), "a malformed spec disables the whole list");
    std::env::remove_var("PALLAS_NUMFAULT");
}

#[test]
fn fault_env_parses_like_pallas_threads() {
    // no other test in this binary reads PALLAS_FAULT from the env (the
    // sweep drives TrainOptions::fault_at directly), so this is race-free
    use fp4train::refmodel::engine::fault_from_env;
    std::env::remove_var("PALLAS_FAULT");
    assert_eq!(fault_from_env(), None);
    std::env::set_var("PALLAS_FAULT", "23");
    assert_eq!(fault_from_env(), Some(23));
    std::env::set_var("PALLAS_FAULT", " 7 ");
    assert_eq!(fault_from_env(), Some(7));
    std::env::set_var("PALLAS_FAULT", "not-a-step");
    assert_eq!(fault_from_env(), None);
    std::env::remove_var("PALLAS_FAULT");
}
