//! Durable orchestration end-to-end: crash → resume is bit-identical to an
//! uninterrupted run (the ROADMAP's headline verify), the run store's
//! lease machinery survives process death, and corrupted checkpoints fail
//! loudly with the offending path.
//!
//! The fault sweep drives `TrainOptions::fault_at` (the in-process form of
//! `PALLAS_FAULT`) at three structurally different steps: before the first
//! checkpoint (full replay from init), mid-run between checkpoints, and
//! exactly at the §3.3 stage boundary where the recipe swaps to the
//! target.  Every surviving loss bit and every final master-parameter bit
//! must match the uninterrupted reference.

use std::path::{Path, PathBuf};

use fp4train::config::RunConfig;
use fp4train::coordinator::runstore::{LeaseState, RunStatus, RunStore};
use fp4train::refmodel::{train_host_with, HostRunResult, TrainOptions};

fn tdir(name: &str) -> PathBuf {
    let d = std::env::temp_dir().join("fp4orch").join(name);
    let _ = std::fs::remove_dir_all(&d);
    std::fs::create_dir_all(&d).unwrap();
    d
}

/// Tiny-but-real geometry: 8 steps, checkpoints every 2, stage boundary
/// at step 6 (tail frac 0.25), same corpus scale as the engine's
/// reproducibility test.
fn micro_cfg(root: &Path, tag: &str, workers: usize) -> RunConfig {
    let mut cfg = RunConfig::default();
    cfg.model = "gpt2-s-proxy".into();
    cfg.recipe = "ours".into();
    cfg.steps = 8;
    cfg.workers = workers;
    cfg.eval_every = 8;
    cfg.log_every = 8;
    cfg.checkpoint_every = 2;
    cfg.target_precision_frac = 0.25;
    cfg.data.n_docs = 220;
    cfg.out_dir = root.join(tag).to_str().unwrap().to_string();
    cfg
}

/// Every master-parameter bit of a finished run.
fn param_bits(res: HostRunResult) -> Vec<u32> {
    let mut model = res.model;
    let mut bits = Vec::new();
    for (_, p) in model.params_mut() {
        bits.extend(p.iter().map(|v| v.to_bits()));
    }
    bits
}

fn durable(run_dir: PathBuf) -> TrainOptions {
    TrainOptions { run_dir: Some(run_dir), ..Default::default() }
}

#[test]
fn crash_resume_bit_identical_sweep() {
    let root = tdir("sweep");
    // uninterrupted durable reference
    let ref_res =
        train_host_with(&micro_cfg(&root, "ref", 1), &durable(root.join("ref_run"))).unwrap();
    let ref_losses: Vec<u32> = ref_res.metrics.steps.iter().map(|s| s.loss.to_bits()).collect();
    assert_eq!(ref_losses.len(), 8);
    let ref_bits = param_bits(ref_res);

    // k=1: before the first checkpoint (resume = full replay from init);
    // k=3: between checkpoints, mid-run; k=6: the §3.3 stage boundary
    for k in [1u64, 3, 6] {
        let run_dir = root.join(format!("run_k{k}"));
        let cfg = micro_cfg(&root, &format!("k{k}"), 1);
        let mut opts = durable(run_dir.clone());
        opts.fault_at = Some(k);
        let err = format!("{:#}", train_host_with(&cfg, &opts).unwrap_err());
        assert!(err.contains("injected fault"), "k={k}: {err}");

        // the store recorded the fault (best-effort audit)
        let store = RunStore::open(&run_dir).unwrap();
        assert_eq!(store.status(), RunStatus::Faulted, "k={k}");
        drop(store);

        // resume to completion in a fresh "process"
        let opts = TrainOptions { run_dir: Some(run_dir.clone()), resume: true, ..Default::default() };
        let res = train_host_with(&cfg, &opts).unwrap();

        // every replayed step's loss is byte-identical to the reference
        assert!(!res.metrics.steps.is_empty(), "k={k}");
        for r in &res.metrics.steps {
            assert_eq!(
                r.loss.to_bits(),
                ref_losses[r.step as usize],
                "k={k}: loss diverged at step {}",
                r.step
            );
        }
        // final loss byte-identical (the headline acceptance check)
        assert_eq!(
            res.metrics.steps.last().unwrap().loss.to_bits(),
            *ref_losses.last().unwrap(),
            "k={k}: final loss"
        );
        // and every final master-parameter bit matches
        assert_eq!(param_bits(res), ref_bits, "k={k}: param bits diverged");

        // the run store converged to Complete with all shards done
        let store = RunStore::open(&run_dir).unwrap();
        assert_eq!(store.status(), RunStatus::Complete, "k={k}");
        assert!(store.leases().iter().all(|l| l.state == LeaseState::Done), "k={k}");
        assert_eq!(store.resumes(), 1, "k={k}");
    }
}

#[test]
fn crash_resume_bit_identical_with_sharded_workers() {
    // W=2: per-shard grads merged in ascending-shard order; a crash and
    // resume re-leases both shards and must reproduce the same bits
    let root = tdir("sharded");
    let ref_res =
        train_host_with(&micro_cfg(&root, "ref", 2), &durable(root.join("ref_run"))).unwrap();
    let ref_losses: Vec<u32> = ref_res.metrics.steps.iter().map(|s| s.loss.to_bits()).collect();
    let ref_bits = param_bits(ref_res);

    let run_dir = root.join("chaos_run");
    let cfg = micro_cfg(&root, "chaos", 2);
    let mut opts = durable(run_dir.clone());
    opts.fault_at = Some(3);
    assert!(train_host_with(&cfg, &opts).is_err());
    let opts = TrainOptions { run_dir: Some(run_dir), resume: true, ..Default::default() };
    let res = train_host_with(&cfg, &opts).unwrap();
    for r in &res.metrics.steps {
        assert_eq!(r.loss.to_bits(), ref_losses[r.step as usize], "step {}", r.step);
    }
    assert_eq!(param_bits(res), ref_bits, "sharded param bits diverged");
}

#[test]
fn resume_rejects_drifted_config() {
    let root = tdir("drift");
    let cfg = micro_cfg(&root, "a", 1);
    let run_dir = root.join("run");
    let mut opts = durable(run_dir.clone());
    opts.fault_at = Some(2);
    assert!(train_host_with(&cfg, &opts).is_err());
    // resume with a different seed must fail loudly, not drift silently
    let mut drifted = cfg.clone();
    drifted.seed += 1;
    let opts = TrainOptions { run_dir: Some(run_dir), resume: true, ..Default::default() };
    let err = format!("{:#}", train_host_with(&drifted, &opts).unwrap_err());
    assert!(err.contains("config mismatch"), "{err}");
}

#[test]
fn fresh_run_refuses_existing_run_dir_and_complete_runs_refuse_resume() {
    let root = tdir("refuse");
    let cfg = micro_cfg(&root, "a", 1);
    let run_dir = root.join("run");
    train_host_with(&cfg, &durable(run_dir.clone())).unwrap();
    // same dir without --resume: refuse to clobber
    let err = format!("{:#}", train_host_with(&cfg, &durable(run_dir.clone())).unwrap_err());
    assert!(err.contains("--resume"), "{err}");
    // resume of a complete run: nothing to do, says so
    let opts = TrainOptions { run_dir: Some(run_dir), resume: true, ..Default::default() };
    let err = format!("{:#}", train_host_with(&cfg, &opts).unwrap_err());
    assert!(err.contains("already complete"), "{err}");
}

#[test]
fn truncated_checkpoint_fails_resume_with_path() {
    let root = tdir("truncated");
    let cfg = micro_cfg(&root, "a", 1);
    let run_dir = root.join("run");
    let mut opts = durable(run_dir.clone());
    opts.fault_at = Some(5); // checkpoints exist at steps 2 and 4
    assert!(train_host_with(&cfg, &opts).is_err());
    // corrupt the latest checkpoint the way a torn disk would: cut bytes
    let store = RunStore::open(&run_dir).unwrap();
    let (step, ckpt) = store.latest_checkpoint().unwrap();
    assert_eq!(step, 4);
    drop(store);
    let bytes = std::fs::read(&ckpt).unwrap();
    std::fs::write(&ckpt, &bytes[..bytes.len() / 2]).unwrap();
    let opts = TrainOptions { run_dir: Some(run_dir), resume: true, ..Default::default() };
    let err = format!("{:#}", train_host_with(&cfg, &opts).unwrap_err());
    assert!(
        err.contains(ckpt.file_name().unwrap().to_str().unwrap()),
        "error must name the corrupt file: {err}"
    );
    assert!(
        err.contains("truncated") || err.contains("checksum") || err.contains("decompressing"),
        "error must name the failure mode: {err}"
    );
}

#[test]
fn fault_env_parses_like_pallas_threads() {
    // no other test in this binary reads PALLAS_FAULT from the env (the
    // sweep drives TrainOptions::fault_at directly), so this is race-free
    use fp4train::refmodel::engine::fault_from_env;
    std::env::remove_var("PALLAS_FAULT");
    assert_eq!(fault_from_env(), None);
    std::env::set_var("PALLAS_FAULT", "23");
    assert_eq!(fault_from_env(), Some(23));
    std::env::set_var("PALLAS_FAULT", " 7 ");
    assert_eq!(fault_from_env(), Some(7));
    std::env::set_var("PALLAS_FAULT", "not-a-step");
    assert_eq!(fault_from_env(), None);
    std::env::remove_var("PALLAS_FAULT");
}
