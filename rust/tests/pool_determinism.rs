//! Pool determinism: every parallel kernel must produce bit-identical
//! output at thread counts 1, 2, 3, and 8, forced via `PALLAS_THREADS`.
//!
//! The persistent pool (`kernels::pool`) only schedules — work splitting
//! stays on group/row boundaries in the kernels — so the thread-count
//! policy must never move a single output bit.  This file pins that
//! contract for the three kernel families (`fake_quant_rows_auto`,
//! `matmul_f32`, `qgemm`), including the qgemm panel-cache miss and hit
//! paths.
//!
//! `PALLAS_THREADS` is re-read by `pool::configured_threads()` on every
//! call, so setting it between runs inside one process changes the task
//! splitting immediately (the pool's worker count is fixed at first use —
//! it is initialized at 8 here, before the sweep, so the higher counts
//! exercise real cross-thread scheduling too).  Integration tests run in
//! their own process, so the env mutation cannot leak into other suites.

use fp4train::formats::{Granularity, FP4_E2M1, FP8_E4M3};
use fp4train::kernels::{fake_quant_rows_auto, matmul_f32, qgemm_bt_into, qgemm_into, Workspace};
use fp4train::quant::{self, GranSpec};
use fp4train::util::rng::Rng;

const THREAD_COUNTS: [usize; 4] = [8, 3, 2, 1]; // 8 first: pool inits at full width

/// Serializes the tests in this binary: the panel-cache stat assertions
/// need PALLAS_THREADS stable for the duration of a pass (the *results*
/// are thread-count-invariant, but stripe layout — and therefore which
/// panel keys a pass touches — is not).
static ENV_LOCK: std::sync::Mutex<()> = std::sync::Mutex::new(());

fn set_threads(n: usize) {
    std::env::set_var("PALLAS_THREADS", n.to_string());
}

fn bits(v: &[f32]) -> Vec<u32> {
    v.iter().map(|x| x.to_bits()).collect()
}

fn randvec(n: usize, seed: u64) -> Vec<f32> {
    let mut rng = Rng::new(seed);
    (0..n).map(|_| rng.normal_f32(0.0, 1.0)).collect()
}

#[test]
fn kernels_bit_identical_across_thread_counts() {
    let _env = ENV_LOCK.lock().unwrap_or_else(|e| e.into_inner());
    // --- inputs sized past every parallel threshold ---
    // fake-quant sweep: 1024*129 = 132k elems > PAR_MIN_ELEMS, odd cols
    let (qrows, qcols) = (1024usize, 129usize);
    let qx = randvec(qrows * qcols, 51);
    // f32 GEMM: 256*256*128 ≈ 8.4M MACs > PAR_MIN_FLOPS
    let (fm, fk, fn_) = (256usize, 256usize, 128usize);
    let fa = randvec(fm * fk, 52);
    let fb = randvec(fk * fn_, 53);
    // qgemm, column-split shape (ragged last stripe) and narrow row-split
    // shape, both > PAR_MIN_FLOPS
    let (cm, ck, cn) = (64usize, 512usize, 640usize);
    let ca = randvec(cm * ck, 54);
    let cq = quant::quantize_rows(&randvec(ck * cn, 55), ck, cn, FP4_E2M1, GranSpec::PerBlock(128));
    let (rm, rk, rn) = (512usize, 256usize, 64usize);
    let ra = randvec(rm * rk, 56);
    let rq = quant::quantize_rows(&randvec(rk * rn, 57), rk, rn, FP8_E4M3, GranSpec::PerRow);

    let mut reference: Option<(Vec<u32>, Vec<u32>, Vec<u32>, Vec<u32>, Vec<u32>, Vec<u32>)> = None;
    for nt in THREAD_COUNTS {
        set_threads(nt);

        let fq = fake_quant_rows_auto(&qx, qrows, qcols, FP4_E2M1, Granularity::PerBlock(43));
        let mm = matmul_f32(&fa, &fb, fm, fk, fn_);

        // qgemm three ways per thread count: uncached, cache-miss pass
        // (fresh cache), cache-hit pass (same cache, second call)
        let mut plain = vec![0.0f32; cm * cn];
        qgemm_into(&ca, &cq, cm, ck, cn, &mut plain, &mut Workspace::new());
        let mut cws = Workspace::with_panel_cache(64 << 20);
        let mut miss = vec![f32::NAN; cm * cn];
        qgemm_into(&ca, &cq, cm, ck, cn, &mut miss, &mut cws);
        let s = cws.panel_cache_stats().unwrap();
        assert!(s.misses > 0 && s.hits == 0, "nt={nt} first pass must all-miss: {s:?}");
        let mut hit = vec![f32::NAN; cm * cn];
        qgemm_into(&ca, &cq, cm, ck, cn, &mut hit, &mut cws);
        let s2 = cws.panel_cache_stats().unwrap();
        assert!(s2.hits > 0 && s2.misses == s.misses, "nt={nt} second pass must replay: {s2:?}");

        // narrow output → the A-row split fallback, cached and not
        let mut narrow = vec![0.0f32; rm * rn];
        qgemm_into(&ra, &rq, rm, rk, rn, &mut narrow, &mut cws);

        let got = (bits(&fq), bits(&mm), bits(&plain), bits(&miss), bits(&hit), bits(&narrow));
        match &reference {
            None => {
                // sanity anchor for the packed paths before pinning
                let want = matmul_f32(&ca, &quant::dequantize(&cq).data, cm, ck, cn);
                assert_eq!(got.2, bits(&want), "qgemm != dequant+matmul at nt={nt}");
                reference = Some(got);
            }
            Some(r) => {
                assert_eq!(&got.0, &r.0, "fake_quant_rows_auto diverged at nt={nt}");
                assert_eq!(&got.1, &r.1, "matmul_f32 diverged at nt={nt}");
                assert_eq!(&got.2, &r.2, "qgemm (uncached) diverged at nt={nt}");
                assert_eq!(&got.3, &r.3, "qgemm (cache miss) diverged at nt={nt}");
                assert_eq!(&got.4, &r.4, "qgemm (cache hit) diverged at nt={nt}");
                assert_eq!(&got.5, &r.5, "qgemm (row split) diverged at nt={nt}");
            }
        }
    }
    std::env::remove_var("PALLAS_THREADS");
}

#[test]
fn qgemm_bt_bit_identical_across_thread_counts_and_cache_states() {
    let _env = ENV_LOCK.lock().unwrap_or_else(|e| e.into_inner());
    // transposed orientation: B stored (n, k), K-grouped.  Column-split
    // shape (ragged last stripe) plus the narrow-output A-row fallback,
    // both past PAR_MIN_FLOPS; each swept at PALLAS_THREADS {1, 2, 8} ×
    // {uncached, cache-miss, cache-hit} — the transposed-path mirror of
    // `kernels_bit_identical_across_thread_counts`.
    let (cm, ck, cn) = (64usize, 512usize, 640usize);
    let ca = randvec(cm * ck, 61);
    let cq = quant::quantize_rows(&randvec(cn * ck, 62), cn, ck, FP4_E2M1, GranSpec::PerBlock(128));
    let (rm, rk, rn) = (512usize, 256usize, 64usize);
    let ra = randvec(rm * rk, 63);
    let rq = quant::quantize_rows(&randvec(rn * rk, 64), rn, rk, FP8_E4M3, GranSpec::PerRow);

    let mut reference: Option<(Vec<u32>, Vec<u32>, Vec<u32>, Vec<u32>)> = None;
    for nt in [8usize, 2, 1] {
        set_threads(nt);
        let mut plain = vec![0.0f32; cm * cn];
        qgemm_bt_into(&ca, &cq, cm, ck, cn, &mut plain, &mut Workspace::new());
        let mut cws = Workspace::with_panel_cache(64 << 20);
        let mut miss = vec![f32::NAN; cm * cn];
        qgemm_bt_into(&ca, &cq, cm, ck, cn, &mut miss, &mut cws);
        let s = cws.panel_cache_stats().unwrap();
        assert!(s.misses > 0 && s.hits == 0, "nt={nt} first bt pass must all-miss: {s:?}");
        let mut hit = vec![f32::NAN; cm * cn];
        qgemm_bt_into(&ca, &cq, cm, ck, cn, &mut hit, &mut cws);
        let s2 = cws.panel_cache_stats().unwrap();
        assert!(s2.hits > 0 && s2.misses == s.misses, "nt={nt} second bt pass must replay: {s2:?}");
        // narrow output → the A-row split fallback, through the same cache
        let mut narrow = vec![0.0f32; rm * rn];
        qgemm_bt_into(&ra, &rq, rm, rk, rn, &mut narrow, &mut cws);

        let got = (bits(&plain), bits(&miss), bits(&hit), bits(&narrow));
        match &reference {
            None => {
                // sanity anchor before pinning: transposed-dequant oracle
                let want =
                    matmul_f32(&ca, &quant::dequantize(&cq).transpose2().data, cm, ck, cn);
                assert_eq!(got.0, bits(&want), "qgemm_bt != dequantᵀ+matmul at nt={nt}");
                reference = Some(got);
            }
            Some(r) => {
                assert_eq!(&got.0, &r.0, "qgemm_bt (uncached) diverged at nt={nt}");
                assert_eq!(&got.1, &r.1, "qgemm_bt (cache miss) diverged at nt={nt}");
                assert_eq!(&got.2, &r.2, "qgemm_bt (cache hit) diverged at nt={nt}");
                assert_eq!(&got.3, &r.3, "qgemm_bt (row split) diverged at nt={nt}");
            }
        }
    }
    std::env::remove_var("PALLAS_THREADS");
}

#[test]
fn configured_threads_env_override_and_clamping() {
    let _env = ENV_LOCK.lock().unwrap_or_else(|e| e.into_inner());
    use fp4train::kernels::pool::{configured_threads, MAX_THREADS};
    set_threads(3);
    assert_eq!(configured_threads(), 3);
    std::env::set_var("PALLAS_THREADS", "10000"); // clamped down
    assert_eq!(configured_threads(), MAX_THREADS);
    // invalid settings are rejected (reported once to stderr) and fall
    // back to the automatic policy — never silently coerced to a thread
    // count.  In particular "0" is an error, not "clamp up to 1".
    std::env::remove_var("PALLAS_THREADS");
    let auto = configured_threads();
    assert!((1..=MAX_THREADS).contains(&auto));
    for bad in ["0", "not a number", "", "-3", "2.5"] {
        std::env::set_var("PALLAS_THREADS", bad);
        assert_eq!(configured_threads(), auto, "invalid PALLAS_THREADS={bad:?}");
    }
    std::env::remove_var("PALLAS_THREADS");
}

#[test]
fn pack_sweep_bit_identical_across_thread_counts() {
    let _env = ENV_LOCK.lock().unwrap_or_else(|e| e.into_inner());
    // quantize+pack has the extra FP4 chunk-evening rule — sweep it too
    let (rows, cols) = (1024usize, 129usize);
    let x = randvec(rows * cols, 58);
    let mut reference: Option<(Vec<u8>, Vec<u32>)> = None;
    for nt in THREAD_COUNTS {
        set_threads(nt);
        let q = quant::quantize_rows(&x, rows, cols, FP4_E2M1, GranSpec::PerBlock(43));
        let got = (q.packed.clone(), q.scales.iter().map(|s| s.to_bits()).collect::<Vec<_>>());
        match &reference {
            None => reference = Some(got),
            Some(r) => assert_eq!(&got, r, "quantize_pack diverged at nt={nt}"),
        }
    }
    std::env::remove_var("PALLAS_THREADS");
}

#[test]
fn transposed_pack_bit_identical_across_thread_counts() {
    let _env = ENV_LOCK.lock().unwrap_or_else(|e| e.into_inner());
    // quantize_rows_t row-fans across the pool above PAR_MIN_ELEMS (the
    // per-optimizer-step weight repack); sweep it like the flat pack,
    // including the single-scale PerTensor row split and a ragged last
    // row chunk (129 output rows)
    let (rows, cols) = (1024usize, 129usize); // output geometry: 129 x 1024
    let x = randvec(rows * cols, 59);
    let mut reference: Option<Vec<(Vec<u8>, Vec<u32>, Vec<u8>)>> = None;
    for nt in THREAD_COUNTS {
        set_threads(nt);
        let got: Vec<(Vec<u8>, Vec<u32>, Vec<u8>)> = [
            GranSpec::PerBlock(128),
            GranSpec::PerRow,
            GranSpec::PerTensor,
            GranSpec::TwoLevelBlock(128),
        ]
        .into_iter()
        .map(|g| {
            let q = quant::quantize_rows_t(&x, rows, cols, FP4_E2M1, g);
            assert_eq!(q.rows_cols(), (cols, rows));
            let plane = q.scale_plane.as_ref().map(|p| p.codes.clone()).unwrap_or_default();
            (q.packed.clone(), q.scales.iter().map(|s| s.to_bits()).collect(), plane)
        })
        .collect();
        match &reference {
            None => reference = Some(got),
            Some(r) => assert_eq!(&got, r, "quantize_rows_t diverged at nt={nt}"),
        }
    }
    std::env::remove_var("PALLAS_THREADS");
}

#[test]
fn sr_and_two_level_sweeps_bit_identical_across_thread_counts() {
    let _env = ENV_LOCK.lock().unwrap_or_else(|e| e.into_inner());
    use fp4train::kernels::fake_quant_rows_sr_auto;
    // 1024*129 elems > PAR_MIN_ELEMS with odd cols and a 43-wide block
    // (129 = 3*43 → ragged chunk evening on the FP4 pack path).  The SR
    // draws are counter-based on (key, flat index), so every thread count
    // must reproduce the serial stream exactly — for the plain-block and
    // the two-level gradient-quant paths alike.
    let (rows, cols) = (1024usize, 129usize);
    let x = randvec(rows * cols, 65);
    const KEY: u64 = 0x5EED_C0FFEE;
    let mut reference: Option<(Vec<u32>, Vec<u32>, Vec<u32>, (Vec<u8>, Vec<u32>, Vec<u8>, u32))> =
        None;
    for nt in THREAD_COUNTS {
        set_threads(nt);
        let sr_block =
            fake_quant_rows_sr_auto(&x, rows, cols, FP4_E2M1, Granularity::PerBlock(43), KEY);
        let sr_two =
            fake_quant_rows_sr_auto(&x, rows, cols, FP4_E2M1, Granularity::TwoLevelBlock(43), KEY);
        let fq_two =
            fake_quant_rows_auto(&x, rows, cols, FP4_E2M1, Granularity::TwoLevelBlock(43));
        let q = quant::quantize_rows(&x, rows, cols, FP4_E2M1, GranSpec::TwoLevelBlock(43));
        let plane = q.scale_plane.as_ref().expect("two-level pack carries a plane");
        let got = (
            bits(&sr_block),
            bits(&sr_two),
            bits(&fq_two),
            (
                q.packed.clone(),
                q.scales.iter().map(|s| s.to_bits()).collect::<Vec<_>>(),
                plane.codes.clone(),
                plane.tensor_scale.to_bits(),
            ),
        );
        match &reference {
            None => reference = Some(got),
            Some(r) => {
                assert_eq!(&got.0, &r.0, "SR per-block sweep diverged at nt={nt}");
                assert_eq!(&got.1, &r.1, "SR two-level sweep diverged at nt={nt}");
                assert_eq!(&got.2, &r.2, "two-level fake-quant diverged at nt={nt}");
                assert_eq!(&got.3, &r.3, "two-level pack diverged at nt={nt}");
            }
        }
    }
    std::env::remove_var("PALLAS_THREADS");
}
