//! Refmodel training determinism: quantized host training must be
//! bit-identical at `PALLAS_THREADS` ∈ {1, 2, 8} and with the qgemm panel
//! cache on or off.  The geometry is sized past every kernel parallel
//! threshold (fake-quant sweeps > `PAR_MIN_ELEMS`, GEMMs >
//! `PAR_MIN_FLOPS`), so the thread sweep exercises real cross-thread
//! scheduling, not the serial fallbacks.
//!
//! Same env-lock discipline as `tests/pool_determinism.rs`: thread count
//! is process-global, so the sweep serializes on a mutex and this file
//! runs in its own test binary.

use fp4train::refmodel::engine::{AdamW, HParams};
use fp4train::refmodel::qlinear::Scratch;
use fp4train::refmodel::{presets, RefConfig, RefModel};
use fp4train::tensor::TensorI32;
use fp4train::util::rng::Rng;

const THREAD_COUNTS: [usize; 3] = [8, 2, 1]; // 8 first: pool inits at full width

static ENV_LOCK: std::sync::Mutex<()> = std::sync::Mutex::new(());

fn micro_cfg() -> RefConfig {
    RefConfig {
        name: "determinism-proxy".into(),
        family: "gpt2".into(),
        vocab: 64,
        layers: 2,
        d_model: 128,
        n_head: 4,
        d_ff: 512,
        seq: 64,
        rope: false,
    }
}

/// LLaMA-block twin of `micro_cfg`: RoPE attention, SwiGLU FFN, rmsnorm.
/// Same past-every-parallel-threshold sizing so the thread sweep hits the
/// concurrent kernel paths (including the KV / attention-probs fake-quant
/// sweeps added by the `ours_qattn` recipe).
fn micro_llama_cfg() -> RefConfig {
    RefConfig {
        name: "determinism-llama-proxy".into(),
        family: "llama".into(),
        vocab: 64,
        layers: 2,
        d_model: 128,
        n_head: 4,
        d_ff: 384,
        seq: 64,
        rope: true,
    }
}

/// Deterministic synthetic batch for a step (no corpus/tokenizer needed).
fn batch_at(step: u64, b: usize, t: usize, vocab: usize) -> TensorI32 {
    let mut rng = Rng::new(0xBA7C4 ^ step);
    let data: Vec<i32> = (0..b * (t + 1)).map(|_| rng.below(vocab as u64) as i32).collect();
    TensorI32::from_vec(&[b, t + 1], data)
}

/// Train `steps` quantized steps and return every final master-parameter
/// bit plus the per-step losses.
fn train_bits(steps: u64, panel_cache: bool) -> (Vec<u32>, Vec<u32>) {
    train_bits_recipe("ours", steps, panel_cache)
}

fn train_bits_recipe(recipe: &str, steps: u64, panel_cache: bool) -> (Vec<u32>, Vec<u32>) {
    train_bits_cfg(micro_cfg(), recipe, steps, panel_cache)
}

fn train_bits_cfg(
    cfg: RefConfig,
    recipe: &str,
    steps: u64,
    panel_cache: bool,
) -> (Vec<u32>, Vec<u32>) {
    let recipe = presets::recipe(recipe).unwrap();
    let family = cfg.family.clone();
    let mut model = RefModel::new(cfg.clone(), recipe, 17);
    let mut opt = AdamW::new(&mut model, HParams::for_family(&family, steps));
    let mut sc = if panel_cache { Scratch::with_panel_cache(64 << 20) } else { Scratch::default() };
    let b = 8;
    let mut losses = Vec::new();
    for step in 0..steps {
        let batch = batch_at(step, b, cfg.seq, cfg.vocab);
        let (loss, grads, _) = model.loss_and_grads(&batch, &mut sc);
        losses.push(loss.to_bits());
        opt.step(&mut model, &grads).unwrap();
        model.refresh_packed();
    }
    let mut bits = Vec::new();
    for (_, p) in model.params_mut() {
        bits.extend(p.iter().map(|v| v.to_bits()));
    }
    (bits, losses)
}

#[test]
fn quantized_training_bit_identical_across_threads_and_cache() {
    let _env = ENV_LOCK.lock().unwrap_or_else(|e| e.into_inner());
    let mut reference: Option<(Vec<u32>, Vec<u32>)> = None;
    for nt in THREAD_COUNTS {
        std::env::set_var("PALLAS_THREADS", nt.to_string());
        for cache in [false, true] {
            let got = train_bits(3, cache);
            match &reference {
                None => reference = Some(got),
                Some(r) => {
                    assert_eq!(got.1, r.1, "loss bits diverged at nt={nt} cache={cache}");
                    assert_eq!(got.0, r.0, "param bits diverged at nt={nt} cache={cache}");
                }
            }
        }
    }
    std::env::remove_var("PALLAS_THREADS");
}

/// Same sweep on the `nvfp4_sr` recipe: two-level block-scaled FFN
/// operands AND stochastically-rounded gradient fake-quants.  The SR
/// draws are counter-based (keyed on linear name + absolute element
/// index), so the training trajectory must stay bit-identical at every
/// thread count and panel-cache state — the determinism claim the
/// counter-based design exists to make.  The SR trajectory must also
/// actually differ from the RNE trajectory of the same geometry
/// (`nvfp4`), or the knob is dead.
#[test]
fn sr_two_level_training_bit_identical_across_threads_and_cache() {
    let _env = ENV_LOCK.lock().unwrap_or_else(|e| e.into_inner());
    let mut reference: Option<(Vec<u32>, Vec<u32>)> = None;
    for nt in THREAD_COUNTS {
        std::env::set_var("PALLAS_THREADS", nt.to_string());
        for cache in [false, true] {
            let got = train_bits_recipe("nvfp4_sr", 3, cache);
            match &reference {
                None => reference = Some(got),
                Some(r) => {
                    assert_eq!(got.1, r.1, "SR loss bits diverged at nt={nt} cache={cache}");
                    assert_eq!(got.0, r.0, "SR param bits diverged at nt={nt} cache={cache}");
                }
            }
        }
    }
    std::env::remove_var("PALLAS_THREADS");
    let rne = train_bits_recipe("nvfp4", 3, false);
    let sr = reference.unwrap();
    assert_ne!(rne.1, sr.1, "SR gradient rounding changed no loss bit vs RNE");
}

/// Same sweep on the LLaMA block under the `ours_qattn` recipe: RoPE
/// attention with an FP8-fake-quantized KV write and FP8 attention probs
/// on top of the quantized linears.  The KV and probs fake-quant sweeps
/// run over `(b*h*t, dh)` / `(b*h*t, t)` row matrices sized past
/// `PAR_MIN_ELEMS`, so this pins the new quantization points (and the
/// whole llama fwd/bwd) bit-identical across thread counts and
/// panel-cache states.  The attention quantizers must also actually move
/// the trajectory vs plain `ours` on the same block, or the knobs are
/// dead.
#[test]
fn llama_qattn_training_bit_identical_across_threads_and_cache() {
    let _env = ENV_LOCK.lock().unwrap_or_else(|e| e.into_inner());
    let mut reference: Option<(Vec<u32>, Vec<u32>)> = None;
    for nt in THREAD_COUNTS {
        std::env::set_var("PALLAS_THREADS", nt.to_string());
        for cache in [false, true] {
            let got = train_bits_cfg(micro_llama_cfg(), "ours_qattn", 3, cache);
            match &reference {
                None => reference = Some(got),
                Some(r) => {
                    assert_eq!(got.1, r.1, "llama qattn loss bits diverged at nt={nt} cache={cache}");
                    assert_eq!(got.0, r.0, "llama qattn param bits diverged at nt={nt} cache={cache}");
                }
            }
        }
    }
    std::env::remove_var("PALLAS_THREADS");
    let plain = train_bits_cfg(micro_llama_cfg(), "ours", 3, false);
    let qattn = reference.unwrap();
    assert_ne!(plain.1, qattn.1, "KV/probs quantization changed no loss bit vs plain ours");
}

#[test]
fn training_descends_and_schedule_swaps_to_exact() {
    let _env = ENV_LOCK.lock().unwrap_or_else(|e| e.into_inner());
    std::env::remove_var("PALLAS_THREADS");
    let cfg = micro_cfg();
    let recipe = presets::recipe("ours").unwrap();
    let target = presets::recipe("fp16").unwrap();
    let mut model = RefModel::new(cfg.clone(), recipe, 3);
    let steps = 12u64;
    let stage1 = 9u64;
    let mut opt = AdamW::new(&mut model, HParams::for_family("gpt2", steps));
    let mut sc = Scratch::default();
    let mut first = f32::NAN;
    let mut last = f32::NAN;
    for step in 0..steps {
        if step == stage1 {
            model.set_recipe(target.clone());
            assert_eq!(model.recipe().name, "fp16");
        }
        let batch = batch_at(step % 2, 8, cfg.seq, cfg.vocab); // 2 alternating batches
        let (loss, grads, _) = model.loss_and_grads(&batch, &mut sc);
        if step == 0 {
            first = loss;
        }
        last = loss;
        assert!(loss.is_finite(), "step {step}");
        opt.step(&mut model, &grads).unwrap();
        model.refresh_packed();
    }
    assert!(last < first, "loss did not descend: {first} -> {last}");
}

/// Train-level guard for the K-grouped dx rewiring: the packed state is a
/// pure function of (master weight, recipe), so gratuitous repacks —
/// extra `refresh_packed` calls, or a `set_recipe` swap to the *same*
/// recipe (the §3.3 stage-boundary machinery, now repacking one canonical
/// K-grouped tensor per linear) — must not move a byte of any loss.
/// Together with qlinear's `packed_direct_fwd_dx_match_old_decode_dataflow
/// _bitwise` (per-GEMM: new packed-direct dataflow == old decode-to-f32
/// dataflow on the same geometry) this pins "byte-identical losses before
/// and after the rewiring with the geometry held fixed": losses are a
/// deterministic function of those per-layer outputs.
#[test]
fn repacks_and_same_recipe_swaps_keep_losses_byte_identical() {
    let _env = ENV_LOCK.lock().unwrap_or_else(|e| e.into_inner());
    std::env::remove_var("PALLAS_THREADS");
    let cfg = micro_cfg();
    let recipe = presets::recipe("ours").unwrap();
    let steps = 4u64;
    let run = |perturb: bool| -> Vec<u32> {
        let mut model = RefModel::new(cfg.clone(), recipe.clone(), 23);
        let mut opt = AdamW::new(&mut model, HParams::for_family("gpt2", steps));
        let mut sc = Scratch::default();
        let mut losses = Vec::new();
        for step in 0..steps {
            if perturb {
                // no-op churn of the packed state between steps
                model.refresh_packed();
                model.set_recipe(recipe.clone());
            }
            let batch = batch_at(step, 8, cfg.seq, cfg.vocab);
            let (loss, grads, _) = model.loss_and_grads(&batch, &mut sc);
            losses.push(loss.to_bits());
            opt.step(&mut model, &grads).unwrap();
            model.refresh_packed();
        }
        losses
    };
    assert_eq!(run(false), run(true), "repack churn moved a loss bit");
}

/// The engine's full `train_host` entry point is deterministic end to end
/// (corpus → tokenizer → batches → kernels → AdamW): two identical runs
/// produce identical metrics.
#[test]
fn train_host_runs_are_reproducible() {
    let _env = ENV_LOCK.lock().unwrap_or_else(|e| e.into_inner());
    std::env::remove_var("PALLAS_THREADS");
    let dir = std::env::temp_dir().join("refmodel_host_repro");
    let mut cfg = fp4train::config::RunConfig::default();
    cfg.model = "gpt2-s-proxy".into();
    cfg.recipe = "ours".into();
    cfg.steps = 4;
    cfg.eval_every = 4;
    cfg.log_every = 4;
    cfg.target_precision_frac = 0.25; // last step on the exact target recipe
    cfg.data.n_docs = 220;
    cfg.out_dir = dir.to_str().unwrap().to_string();
    let a = fp4train::refmodel::train_host(&cfg).unwrap();
    let b = fp4train::refmodel::train_host(&cfg).unwrap();
    assert_eq!(a.metrics.steps.len(), 4);
    let stages: Vec<u8> = a.metrics.steps.iter().map(|s| s.stage).collect();
    assert_eq!(stages, vec![0, 0, 0, 1]);
    for (ra, rb) in a.metrics.steps.iter().zip(&b.metrics.steps) {
        assert_eq!(ra.loss.to_bits(), rb.loss.to_bits(), "step {}", ra.step);
        assert_eq!(ra.grad_norm.to_bits(), rb.grad_norm.to_bits());
    }
    assert_eq!(a.final_val_nll.to_bits(), b.final_val_nll.to_bits());
    assert!(a.final_val_nll.is_finite());
    // metrics CSVs written with the host tag
    assert!(dir.join("gpt2-s-proxy__ours__host__steps.csv").exists());
    assert!(dir.join("gpt2-s-proxy__ours__host__eval.csv").exists());
}
