//! Golden-vector differential test: replay the checked-in python fixture
//! (`tests/golden/refmodel_micro.json`, dumped by
//! `python/compile/kernels/ref.py::write_refmodel_fixture` and validated
//! there against jax autodiff through the repo's L2 model) through the
//! rust `refmodel` engine and compare activations, loss, and every
//! parameter gradient.
//!
//! Tolerances (also recorded inside the fixture): comparisons are
//! per-tensor **relative L2** because numpy (BLAS) and rust (ascending-k)
//! accumulate f32 matmuls in different orders — on the quantized run an
//! element whose pre-quantization value lands within float roundoff of a
//! rounding boundary may legitimately differ by a full grid step, which
//! per-element equality would misread as a bug.  The fp16 run has no
//! quantizers, so its bound is pure accumulation noise (2e-5); the
//! quantized bounds are format-derived (5e-3 for the gpt2 quant run,
//! wider for the NVFP4+SR and llama + quantized-attention runs, which
//! add more fake-quantized contractions — see the fixture's tolerance
//! comments).

use std::path::Path;

use fp4train::formats::{FpFormat, Granularity};
use fp4train::refmodel::{qlinear::Scratch, QSpec, RecipePrec, RefConfig, RefModel};
use fp4train::tensor::TensorI32;
use fp4train::util::json::Json;

fn fixture() -> Json {
    let p = Path::new("tests/golden/refmodel_micro.json");
    assert!(p.exists(), "golden fixture missing — regenerate with \
        `python3 -m compile.kernels.ref rust/tests/golden/refmodel_micro.json`");
    Json::parse(&std::fs::read_to_string(p).unwrap()).unwrap()
}

fn floats(j: &Json) -> Vec<f32> {
    j.as_arr()
        .expect("array")
        .iter()
        .map(|v| v.as_f64().unwrap() as f32)
        .collect()
}

fn rel_l2(got: &[f32], want: &[f32]) -> f64 {
    assert_eq!(got.len(), want.len());
    let mut num = 0.0f64;
    let mut den = 0.0f64;
    for (&a, &b) in got.iter().zip(want) {
        num += ((a - b) as f64).powi(2);
        den += (b as f64).powi(2);
    }
    num.sqrt() / den.sqrt().max(1e-12)
}

fn config_of_at(j: &Json, root: &str) -> RefConfig {
    let g = |k: &str| j.at(&[root, k]).and_then(|v| v.as_usize()).unwrap();
    let family = j.at(&[root, "family"]).and_then(|v| v.as_str()).unwrap().to_string();
    RefConfig {
        name: format!("refmodel-micro-{family}"),
        rope: family == "llama",
        family,
        vocab: g("vocab"),
        layers: g("layers"),
        d_model: g("d_model"),
        n_head: g("n_head"),
        d_ff: g("d_ff"),
        seq: g("seq"),
    }
}

fn config_of(j: &Json) -> RefConfig {
    config_of_at(j, "config")
}

fn spec_of_at(j: &Json, root: &str, knob: &str) -> Option<QSpec> {
    let fmt = j.at(&[root, knob, "fmt"]).and_then(|v| v.as_str()).unwrap();
    if fmt == "none" {
        return None;
    }
    let block = j.at(&[root, knob, "block"]).and_then(|v| v.as_usize()).unwrap();
    // optional flag: block-grouped FP4 under a two-level scale plane
    let two_level =
        j.at(&[root, knob, "two_level"]).and_then(|v| v.as_bool()).unwrap_or(false);
    let gran = if two_level {
        Granularity::TwoLevelBlock(block)
    } else if block == 0 {
        Granularity::PerRow
    } else {
        Granularity::PerBlock(block)
    };
    Some(QSpec { fmt: FpFormat::by_name(fmt).expect("fixture format"), gran })
}

fn spec_of(j: &Json, knob: &str) -> Option<QSpec> {
    spec_of_at(j, "recipe", knob)
}

fn build_model_at(j: &Json, cfg_root: &str, params_root: &str, recipe: RecipePrec) -> RefModel {
    let cfg = config_of_at(j, cfg_root);
    let mut model = RefModel::new(cfg, recipe, 0);
    let owned: Vec<(String, Vec<f32>)> = j
        .get(params_root)
        .and_then(|p| p.members())
        .unwrap()
        .iter()
        .map(|(name, p)| (name.clone(), floats(p.get("data").unwrap())))
        .collect();
    let entries: Vec<(&str, &[f32])> =
        owned.iter().map(|(n, d)| (n.as_str(), d.as_slice())).collect();
    model.set_params(&entries); // bulk load: one re-pack for all params
    model
}

fn build_model(j: &Json, recipe: RecipePrec) -> RefModel {
    build_model_at(j, "config", "params", recipe)
}

fn batch_of(j: &Json) -> TensorI32 {
    let rows = j.get("batch").and_then(|b| b.as_arr()).unwrap();
    let t1 = rows[0].as_arr().unwrap().len();
    let data: Vec<i32> = rows
        .iter()
        .flat_map(|r| r.as_arr().unwrap().iter().map(|v| v.as_i64().unwrap() as i32))
        .collect();
    TensorI32::from_vec(&[rows.len(), t1], data)
}

fn tol(j: &Json, key: &str) -> f64 {
    j.at(&["tolerances", key]).and_then(|v| v.as_f64()).unwrap()
}

fn replay_at(run: &str, cfg_root: &str, params_root: &str, recipe: RecipePrec, bound_key: &str) {
    let j = fixture();
    let bound = tol(&j, bound_key);
    let loss_tol = tol(&j, "loss_abs");
    let model = build_model_at(&j, cfg_root, params_root, recipe);
    let batch = batch_of(&j);
    let mut sc = Scratch::default();
    let (loss, grads, cache) = model.loss_and_grads(&batch, &mut sc);

    let r = j.at(&["runs", run]).unwrap();
    let want_loss = r.get("loss").and_then(|v| v.as_f64()).unwrap();
    assert!(
        (loss as f64 - want_loss).abs() < loss_tol,
        "{run} loss: rust {loss} vs python {want_loss}"
    );

    let check = |label: &str, got: &[f32], want: &[f32]| {
        let e = rel_l2(got, want);
        assert!(e < bound, "{run}/{label}: rel L2 {e:.3e} > {bound:.1e}");
    };
    check("embed", &cache.x0, &floats(r.get("embed").unwrap()));
    for (i, b) in r.get("block_out").and_then(|b| b.as_arr()).unwrap().iter().enumerate() {
        check(&format!("block_out.{i}"), cache.block_out(i), &floats(b));
    }
    check("final_hidden", &cache.hf, &floats(r.get("final_hidden").unwrap()));
    check("logits", &cache.logits, &floats(r.get("logits").unwrap()));

    let want_grads = r.get("grads").and_then(|g| g.members()).unwrap();
    let got_grads = grads.flat();
    assert_eq!(got_grads.len(), want_grads.len(), "grad count");
    for (name, got) in &got_grads {
        let want = want_grads
            .iter()
            .find(|(n, _)| n == name)
            .unwrap_or_else(|| panic!("fixture missing grad {name}"));
        check(&format!("grad {name}"), got, &floats(&want.1));
    }
}

fn replay(run: &str, recipe: RecipePrec, bound_key: &str) {
    replay_at(run, "config", "params", recipe, bound_key);
}

#[test]
fn fp16_run_matches_python_golden() {
    replay("fp16", RecipePrec::exact("fp16"), "fp16_rel_l2");
}

#[test]
fn quant_run_matches_python_golden() {
    let j = fixture();
    let recipe = RecipePrec {
        name: "fixture-quant".into(),
        attn: spec_of(&j, "attn"),
        ffn: spec_of(&j, "ffn"),
        wgrad: spec_of(&j, "wgrad"),
        agrad: spec_of(&j, "agrad"),
        kv: None,
        attn_probs: None,
        sr_grad: false,
    };
    assert!(recipe.attn.is_some() && recipe.ffn.is_some() && recipe.wgrad.is_some());
    assert!(recipe.agrad.is_none());
    replay("quant", recipe, "quant_rel_l2");
}

/// Replay the NVFP4-style run: two-level block-scaled FFN operands plus
/// counter-based stochastic rounding on the gradient fake-quants.  The
/// python oracle mirrors both (same scale-plane arithmetic, same
/// splitmix64 counter draws keyed by linear name), so the comparison
/// pins the SR draw sequence itself, not just its statistics.
#[test]
fn nvfp4_sr_run_matches_python_golden() {
    let j = fixture();
    let root = "recipe_nvfp4_sr";
    let recipe = RecipePrec {
        name: "fixture-nvfp4-sr".into(),
        attn: spec_of_at(&j, root, "attn"),
        ffn: spec_of_at(&j, root, "ffn"),
        wgrad: spec_of_at(&j, root, "wgrad"),
        agrad: spec_of_at(&j, root, "agrad"),
        kv: None,
        attn_probs: None,
        sr_grad: j.at(&[root, "sr_grad"]).and_then(|v| v.as_bool()).unwrap(),
    };
    assert!(matches!(recipe.ffn.unwrap().gran, Granularity::TwoLevelBlock(_)));
    assert!(recipe.sr_grad);
    replay("nvfp4_sr", recipe, "nvfp4_sr_rel_l2");
}

/// Replay the llama-block + quantized-attention run: rmsnorm / RoPE /
/// SwiGLU forward-backward on the real llama block, with the FP8
/// KV-cache (per (token, head) row along head_dim) and FP8 probs
/// quantizers (per query row along the key axis) engaged — the python
/// oracle mirrors the STE backward exactly (quantized kq/vq/pq in every
/// contraction, raw probs in the softmax backward, inverse-rotation RoPE
/// vjp), so this pins the whole quantized attention interior.
#[test]
fn llama_qattn_run_matches_python_golden() {
    let j = fixture();
    let root = "recipe_llama_qattn";
    let recipe = RecipePrec {
        name: "fixture-llama-qattn".into(),
        attn: spec_of_at(&j, root, "attn"),
        ffn: spec_of_at(&j, root, "ffn"),
        wgrad: spec_of_at(&j, root, "wgrad"),
        agrad: spec_of_at(&j, root, "agrad"),
        kv: spec_of_at(&j, root, "kv"),
        attn_probs: spec_of_at(&j, root, "attn_probs"),
        sr_grad: false,
    };
    // fixture block 0 == one scale group per row
    assert_eq!(recipe.kv.unwrap().gran, Granularity::PerRow);
    assert_eq!(recipe.attn_probs.unwrap().gran, Granularity::PerRow);
    replay_at(
        "llama_qattn",
        "config_llama",
        "params_llama",
        recipe,
        "llama_qattn_rel_l2",
    );
}

/// The attention-interior quantizers must actually engage on the llama
/// block: the same llama model with kv/attn_probs stripped produces a
/// different loss, and the gap stays within a coarse FP8-derived band.
#[test]
fn llama_kv_probs_quantizers_engage() {
    let j = fixture();
    let root = "recipe_llama_qattn";
    let qattn = RecipePrec {
        name: "fixture-llama-qattn".into(),
        attn: spec_of_at(&j, root, "attn"),
        ffn: spec_of_at(&j, root, "ffn"),
        wgrad: spec_of_at(&j, root, "wgrad"),
        agrad: spec_of_at(&j, root, "agrad"),
        kv: spec_of_at(&j, root, "kv"),
        attn_probs: spec_of_at(&j, root, "attn_probs"),
        sr_grad: false,
    };
    let stripped = RecipePrec { kv: None, attn_probs: None, ..qattn.clone() };
    let qm = build_model_at(&j, "config_llama", "params_llama", qattn);
    let sm = build_model_at(&j, "config_llama", "params_llama", stripped);
    let batch = batch_of(&j);
    let mut sc = Scratch::default();
    let (ql, _, _) = qm.loss_and_grads(&batch, &mut sc);
    let (sl, _, _) = sm.loss_and_grads(&batch, &mut sc);
    assert_ne!(ql, sl, "kv/attn_probs quantizers changed nothing");
    assert!(((ql - sl) / sl).abs() < 0.25, "qattn {ql} vs stripped {sl}");
}

/// The quantized and exact runs must actually differ (quantization
/// engages) while losses stay within a coarse format-derived band — the
/// differential-oracle sanity the python suite also pins.
#[test]
fn quant_and_fp16_differ_within_format_band() {
    let j = fixture();
    let quant = RecipePrec {
        name: "fixture-quant".into(),
        attn: spec_of(&j, "attn"),
        ffn: spec_of(&j, "ffn"),
        wgrad: spec_of(&j, "wgrad"),
        agrad: spec_of(&j, "agrad"),
        kv: None,
        attn_probs: None,
        sr_grad: false,
    };
    let qm = build_model(&j, quant);
    let fm = build_model(&j, RecipePrec::exact("fp16"));
    let batch = batch_of(&j);
    let mut sc = Scratch::default();
    let (ql, _, _) = qm.loss_and_grads(&batch, &mut sc);
    let (fl, _, _) = fm.loss_and_grads(&batch, &mut sc);
    assert_ne!(ql, fl);
    assert!(((ql - fl) / fl).abs() < 0.25, "quant {ql} vs fp16 {fl}");
}

/// Per-element format-derived forward bound: the quantized linear output
/// can differ from the exact product by at most the accumulated
/// fake-quant perturbation of its operands, `Σ_k |xq·wq − x·w|` (computed
/// here in f64 from the actual fake-quantized operands) plus f32
/// accumulation slop.
#[test]
fn qlinear_forward_error_within_operand_bound() {
    use fp4train::formats::{fake_quant_rows, FP4_E2M1};
    use fp4train::refmodel::{LinearPrec, QLinear};
    use fp4train::tensor::Tensor;
    use fp4train::util::proptest::prop_check;

    prop_check("qgemm error ≤ operand-perturbation bound", 25, |c| {
        let (m, k, n) = (c.usize_in(2, 8), 32, 24);
        let (x, _, _) = c.f32_mat(m, m, k, k, -3.0, 3.0);
        let (w, _, _) = c.f32_mat(k, k, n, n, -1.0, 1.0);
        let spec = QSpec { fmt: FP4_E2M1, gran: Granularity::PerBlock(8) };
        let prec = LinearPrec { fwd: Some(spec), ..LinearPrec::EXACT };
        let l = QLinear::new(Tensor::from_vec(&[k, n], w.clone()), vec![0.0; n], prec);
        let mut sc = Scratch::default();
        let mut y = vec![0.0f32; m * n];
        l.forward_into(&x, m, false, &mut y, &mut sc);

        let xq = fake_quant_rows(&x, m, k, FP4_E2M1, Granularity::PerBlock(8));
        // the layer quantizes w along its contraction axis K (groups on
        // the trailing axis of wᵀ) — the bound must use the same geometry
        let wq = {
            let mut wt = Vec::new();
            fp4train::tensor::transpose_into(&w, k, n, &mut wt);
            let wtq = fake_quant_rows(&wt, n, k, FP4_E2M1, Granularity::PerBlock(8));
            let mut back = Vec::new();
            fp4train::tensor::transpose_into(&wtq, n, k, &mut back);
            back
        };
        for i in 0..m {
            for jn in 0..n {
                let mut exact = 0.0f64;
                let mut bound = 0.0f64;
                for kk in 0..k {
                    let (xv, wv) = (x[i * k + kk] as f64, w[kk * n + jn] as f64);
                    let (xqv, wqv) = (xq[i * k + kk] as f64, wq[kk * n + jn] as f64);
                    exact += xv * wv;
                    bound += (xqv * wqv - xv * wv).abs();
                }
                let err = (y[i * n + jn] as f64 - exact).abs();
                // slack: f32 accumulation of the k=32 quantized products
                // (worst case k·eps·Σ|terms|, here folded into the bound
                // and exact magnitudes)
                let slack = 3e-4 * (exact.abs() + bound) + 1e-5;
                if err > bound + slack {
                    return Err(format!("({i},{jn}): err {err} > bound {bound}"));
                }
            }
        }
        Ok(())
    });
}
