//! Compile-only stub of the vendored `xla` (xla_extension 0.5.1 + PJRT)
//! bindings.
//!
//! The real crate links the XLA CPU runtime and carries the
//! `ExecuteOptions::untuple_result` patch the runtime layer depends on;
//! it is not vendorable in this checkout (native XLA archive, offline
//! registry).  This stub exposes the exact API surface
//! `fp4train::runtime` uses so the workspace compiles and every non-PJRT
//! code path (formats, kernels, quant, data, analysis, benches, tests)
//! runs.  Every PJRT entry point returns [`Error::Unavailable`] at
//! runtime; `Runtime::open` therefore fails fast with a clear message,
//! which the CLI and benches already handle ("run `make artifacts`").
//!
//! Swap in the real vendored crate by pointing the `xla` path dependency
//! in `rust/Cargo.toml` at it — no source changes needed.

use std::fmt;
use std::path::Path;

#[derive(Debug)]
pub enum Error {
    /// PJRT is not linked in this build (stub crate).
    Unavailable(&'static str),
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Error::Unavailable(what) => write!(
                f,
                "{what}: PJRT unavailable (stub xla crate — vendor the real \
                 xla_extension bindings to run AOT artifacts)"
            ),
        }
    }
}

impl std::error::Error for Error {}

pub type Result<T> = std::result::Result<T, Error>;

/// Element types transferable to/from device buffers.
pub trait NativeType: Copy {}
impl NativeType for f32 {}
impl NativeType for i32 {}
impl NativeType for i64 {}
impl NativeType for u8 {}

pub struct PjRtClient {
    _priv: (),
}

impl PjRtClient {
    pub fn cpu() -> Result<PjRtClient> {
        Err(Error::Unavailable("PjRtClient::cpu"))
    }

    pub fn compile(&self, _comp: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        Err(Error::Unavailable("PjRtClient::compile"))
    }

    pub fn buffer_from_host_buffer<T: NativeType>(
        &self,
        _data: &[T],
        _dims: &[usize],
        _device: Option<usize>,
    ) -> Result<PjRtBuffer> {
        Err(Error::Unavailable("PjRtClient::buffer_from_host_buffer"))
    }
}

pub struct PjRtBuffer {
    _priv: (),
}

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal> {
        Err(Error::Unavailable("PjRtBuffer::to_literal_sync"))
    }
}

pub struct PjRtLoadedExecutable {
    _priv: (),
}

impl PjRtLoadedExecutable {
    /// Execute with untupled outputs: one inner Vec per replica.
    pub fn execute_b(&self, _args: &[&PjRtBuffer]) -> Result<Vec<Vec<PjRtBuffer>>> {
        Err(Error::Unavailable("PjRtLoadedExecutable::execute_b"))
    }
}

pub struct Literal {
    _priv: (),
}

impl Literal {
    pub fn array_shape(&self) -> Result<ArrayShape> {
        Err(Error::Unavailable("Literal::array_shape"))
    }

    pub fn to_vec<T: NativeType>(&self) -> Result<Vec<T>> {
        Err(Error::Unavailable("Literal::to_vec"))
    }
}

pub struct ArrayShape {
    dims: Vec<i64>,
}

impl ArrayShape {
    pub fn dims(&self) -> &[i64] {
        &self.dims
    }
}

pub struct HloModuleProto {
    _priv: (),
}

impl HloModuleProto {
    pub fn from_text_file(_path: impl AsRef<Path>) -> Result<HloModuleProto> {
        Err(Error::Unavailable("HloModuleProto::from_text_file"))
    }
}

pub struct XlaComputation {
    _priv: (),
}

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation { _priv: () }
    }
}
