#!/usr/bin/env bash
# Compare freshly emitted bench JSONs against committed baselines.
#
# `cargo bench --bench bench_<x>` (run from rust/) writes BENCH_<x>.json
# into rust/.  This script matches each fresh file against
# bench/baselines/BENCH_<x>.json by entry name and warns when a median_ns
# regressed by more than the threshold (default 10 %).  Entries present
# on only one side are reported but never fail the run (new benches land
# before their baselines; renames are ROADMAP-documented).
#
#   scripts/bench_diff.sh            # warn only, always exit 0
#   scripts/bench_diff.sh --strict   # exit 1 if any entry regresses
#   BENCH_DIFF_THRESHOLD=25 scripts/bench_diff.sh   # custom % threshold
#
# No-ops (exit 0 with a note) when no fresh BENCH_*.json exist — so
# `tier1.sh --bench-diff` is safe whether or not benches actually ran —
# or when python3 is unavailable.
#
# Refreshing baselines after an intentional perf change, on the
# reference machine:  cp rust/BENCH_*.json bench/baselines/

set -euo pipefail
SCRIPT_DIR="$(cd "$(dirname "$0")" && pwd)"
REPO="$SCRIPT_DIR/.."
FRESH_DIR="$REPO/rust"
BASE_DIR="$REPO/bench/baselines"
THRESHOLD="${BENCH_DIFF_THRESHOLD:-10}"
STRICT=0
[[ "${1:-}" == "--strict" ]] && STRICT=1

shopt -s nullglob
fresh=("$FRESH_DIR"/BENCH_*.json)
if [[ ${#fresh[@]} -eq 0 ]]; then
    echo "bench_diff: no fresh BENCH_*.json in rust/ (benches not run) — nothing to compare"
    exit 0
fi
if ! command -v python3 >/dev/null 2>&1; then
    echo "bench_diff: python3 not available — skipping comparison" >&2
    exit 0
fi

fail=0
for f in "${fresh[@]}"; do
    base="$BASE_DIR/$(basename "$f")"
    if [[ ! -f "$base" ]]; then
        echo "bench_diff: no baseline for $(basename "$f") — copy it to bench/baselines/ to track"
        continue
    fi
    if ! python3 - "$base" "$f" "$THRESHOLD" <<'PY'
import json, sys

base_path, fresh_path, threshold = sys.argv[1], sys.argv[2], float(sys.argv[3])
base = {r["name"]: r for r in json.load(open(base_path))}
fresh = {r["name"]: r for r in json.load(open(fresh_path))}
name = fresh_path.split("/")[-1]
ok = True
for n, r in fresh.items():
    b = base.get(n)
    if b is None:
        print(f"bench_diff: {name}: '{n}' has no baseline entry (new bench?)")
        continue
    old, new = b["median_ns"], r["median_ns"]
    if old <= 0:
        continue
    delta = 100.0 * (new - old) / old
    if delta > threshold:
        print(f"bench_diff: WARNING {name}: '{n}' regressed {delta:+.1f}% "
              f"({old/1e6:.3f} ms -> {new/1e6:.3f} ms, threshold {threshold:.0f}%)")
        ok = False
    else:
        print(f"bench_diff: {name}: '{n}' {delta:+.1f}%")
for n in base:
    if n not in fresh:
        print(f"bench_diff: {name}: baseline entry '{n}' missing from fresh run")
sys.exit(0 if ok else 3)
PY
    then
        fail=1
    fi
done

if [[ $fail -ne 0 ]]; then
    echo "bench_diff: regressions above ${THRESHOLD}% detected"
    [[ $STRICT -eq 1 ]] && exit 1
fi
exit 0
