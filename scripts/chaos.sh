#!/usr/bin/env bash
# Crash/resume smoke loop for the durable `train --host` orchestration.
#
# Runs the same micro training job three ways and demands bit-identical
# final metrics:
#
#   1. an uninterrupted durable run (the reference),
#   2. a run killed by PALLAS_FAULT=<step> mid-flight (must exit nonzero
#      and leave a resumable run store behind),
#   3. `train --host --resume <run-dir>` continuing run 2 to completion.
#
# The last steps.csv row of runs 1 and 3 must agree byte-for-byte on the
# deterministic columns (step,loss,grad_norm,stage — wall-clock step_ms is
# excluded).  This is the shell-level twin of rust/tests/orchestration.rs,
# exercising the real binary + CLI + env-var path instead of the library.
#
# Usage: scripts/chaos.sh            (also: scripts/tier1.sh --chaos)
# No-ops with exit 0 when cargo is absent, like bench_diff.sh.

set -euo pipefail
SCRIPT_DIR="$(cd "$(dirname "$0")" && pwd)"
cd "$SCRIPT_DIR/../rust"

if ! command -v cargo >/dev/null 2>&1; then
    echo "chaos: cargo not found — skipping crash/resume smoke (no-op)"
    exit 0
fi

STEPS=40
FAULT=23
CKPT_EVERY=8
DOCS=220
WORK="$(mktemp -d "${TMPDIR:-/tmp}/fp4chaos.XXXXXX")"
trap 'rm -rf "$WORK"' EXIT

echo "== chaos: build =="
cargo build --release --quiet
BIN=target/release/fp4train

common_args=(train --host --model gpt2-s-proxy --recipe ours
             --steps "$STEPS" --docs "$DOCS" --checkpoint-every "$CKPT_EVERY"
             --eval-every "$STEPS" --log-every "$STEPS")

echo "== chaos: uninterrupted reference run =="
"$BIN" "${common_args[@]}" --out "$WORK/ref_out" --run-dir "$WORK/ref_run"

echo "== chaos: faulted run (PALLAS_FAULT=$FAULT must kill it) =="
if PALLAS_FAULT=$FAULT "$BIN" "${common_args[@]}" \
        --out "$WORK/chaos_out" --run-dir "$WORK/chaos_run"; then
    echo "chaos: FAIL — injected fault did not make the run exit nonzero" >&2
    exit 1
fi
echo "chaos: faulted as expected"

echo "== chaos: resume to completion =="
"$BIN" "${common_args[@]}" --out "$WORK/resume_out" --resume "$WORK/chaos_run"

# compare the deterministic columns of the final step row
ref_row="$(tail -n1 "$WORK/ref_out"/*__steps.csv | cut -d, -f1-4)"
res_row="$(tail -n1 "$WORK/resume_out"/*__steps.csv | cut -d, -f1-4)"
echo "chaos: ref    final row: $ref_row"
echo "chaos: resume final row: $res_row"
if [[ "$ref_row" != "$res_row" ]]; then
    echo "chaos: FAIL — resumed run diverged from the uninterrupted reference" >&2
    exit 1
fi

echo "chaos: OK — crash at step $FAULT resumed bit-identically"
