#!/usr/bin/env bash
# Crash/resume smoke loop for the durable `train --host` orchestration.
#
# Default (single-process) mode runs the same micro training job three
# ways and demands bit-identical final metrics:
#
#   1. an uninterrupted durable run (the reference),
#   2. a run killed by PALLAS_FAULT=<step> mid-flight (must exit nonzero
#      and leave a resumable run store behind),
#   3. `train --host --resume <run-dir>` continuing run 2 to completion.
#
# The last steps.csv row of runs 1 and 3 must agree byte-for-byte on the
# deterministic columns (step,loss,grad_norm,stage — wall-clock step_ms is
# excluded).  This is the shell-level twin of rust/tests/orchestration.rs,
# exercising the real binary + CLI + env-var path instead of the library.
#
# --mp mode runs the multi-process topology instead: a dedicated
# coordinator (`train --host --workers-external 3`) plus three `worker`
# processes rendezvousing on one --run-dir.  One worker is kill -9'd
# mid-run and relaunched; lease expiry re-homes its shards and the
# relaunched process catches up from the latest checkpoint.  Every
# deterministic steps.csv column of the coordinator's output must match
# an uninterrupted in-process `--workers 3` reference byte-for-byte.
#
# --numeric mode injects numeric faults instead of process deaths: a run
# with PALLAS_NUMFAULT=<step>:<nan|spike> must NOT crash — the training-
# health sentinel rolls back to the latest checkpoint, skips the poisoned
# batch window, journals the intervention, and the final deterministic
# steps.csv columns must match a clean run started with the same window
# pre-skipped (--skip-data).
#
# Usage: scripts/chaos.sh           (also: scripts/tier1.sh --chaos)
#        scripts/chaos.sh --mp      (also: scripts/tier1.sh --chaos-mp)
#        scripts/chaos.sh --numeric (also: scripts/tier1.sh --chaos-numeric)
# No-ops with exit 0 when cargo is absent, like bench_diff.sh.

set -euo pipefail
SCRIPT_DIR="$(cd "$(dirname "$0")" && pwd)"
cd "$SCRIPT_DIR/../rust"

MODE=single
for arg in "$@"; do
    case "$arg" in
        --mp) MODE=mp ;;
        --numeric) MODE=numeric ;;
        *) echo "chaos: unknown flag $arg" >&2; exit 64 ;;
    esac
done

if ! command -v cargo >/dev/null 2>&1; then
    echo "chaos: cargo not found — skipping crash/resume smoke (no-op)"
    exit 0
fi

STEPS=40
FAULT=23
CKPT_EVERY=8
DOCS=220
WORK="$(mktemp -d "${TMPDIR:-/tmp}/fp4chaos.XXXXXX")"
trap 'rm -rf "$WORK"' EXIT

echo "== chaos: build =="
cargo build --release --quiet
BIN=target/release/fp4train

# One training job, shared by every run in both modes (determinism gate:
# the run store hashes model/recipe/steps/seed/workers/corpus geometry,
# so coordinator and workers must agree on all of these).
job_args=(--model gpt2-s-proxy --recipe ours
          --steps "$STEPS" --docs "$DOCS" --checkpoint-every "$CKPT_EVERY"
          --eval-every "$STEPS" --log-every "$STEPS")
common_args=(train --host "${job_args[@]}")

if [[ "$MODE" == single ]]; then
    echo "== chaos: uninterrupted reference run =="
    "$BIN" "${common_args[@]}" --out "$WORK/ref_out" --run-dir "$WORK/ref_run"

    echo "== chaos: faulted run (PALLAS_FAULT=$FAULT must kill it) =="
    if PALLAS_FAULT=$FAULT "$BIN" "${common_args[@]}" \
            --out "$WORK/chaos_out" --run-dir "$WORK/chaos_run"; then
        echo "chaos: FAIL — injected fault did not make the run exit nonzero" >&2
        exit 1
    fi
    echo "chaos: faulted as expected"

    echo "== chaos: resume to completion =="
    "$BIN" "${common_args[@]}" --out "$WORK/resume_out" --resume "$WORK/chaos_run"

    # compare the deterministic columns of the final step row
    ref_row="$(tail -n1 "$WORK/ref_out"/*__steps.csv | cut -d, -f1-4)"
    res_row="$(tail -n1 "$WORK/resume_out"/*__steps.csv | cut -d, -f1-4)"
    echo "chaos: ref    final row: $ref_row"
    echo "chaos: resume final row: $res_row"
    if [[ "$ref_row" != "$res_row" ]]; then
        echo "chaos: FAIL — resumed run diverged from the uninterrupted reference" >&2
        exit 1
    fi

    echo "chaos: OK — crash at step $FAULT resumed bit-identically"
    exit 0
fi

if [[ "$MODE" == numeric ]]; then
    # Numeric-fault smoke: PALLAS_NUMFAULT poisons one step's loss/grads;
    # the sentinel must catch it, roll back to the latest checkpoint, skip
    # the poisoned window, and finish with exit 0.  The recovered run's
    # deterministic columns must match a clean run with the same window
    # pre-skipped (--skip-data) — the shell twin of the orchestration.rs
    # sentinel suite.
    NUMSTEP=23   # between checkpoints 16 and 24 → a real rollback + replay

    echo "== chaos[numeric]: clean reference with --skip-data $NUMSTEP =="
    "$BIN" "${common_args[@]}" --out "$WORK/ref_out" \
        --run-dir "$WORK/ref_run" --skip-data "$NUMSTEP" --no-sentinel

    for kind in nan spike; do
        # a spike is finite, so detection needs the z-score armed: short
        # warmup window, threshold far above healthy jitter yet far below
        # the injected x1e4 gradient blow-up
        extra=()
        [[ "$kind" == spike ]] && extra=(--spike-window 4 --spike-zscore 50)

        echo "== chaos[numeric]: PALLAS_NUMFAULT=$NUMSTEP:$kind must recover =="
        if ! PALLAS_NUMFAULT="$NUMSTEP:$kind" "$BIN" "${common_args[@]}" \
                --out "$WORK/${kind}_out" --run-dir "$WORK/${kind}_run" \
                "${extra[@]}"; then
            echo "chaos[numeric]: FAIL — $kind injection made the run exit nonzero" >&2
            exit 1
        fi
        if ! grep -q '"intervention"' "$WORK/${kind}_run/journal.jsonl"; then
            echo "chaos[numeric]: FAIL — no intervention in the $kind run's journal" >&2
            exit 1
        fi

        ref_row="$(tail -n1 "$WORK/ref_out"/*__steps.csv | cut -d, -f1-4)"
        res_row="$(tail -n1 "$WORK/${kind}_out"/*__steps.csv | cut -d, -f1-4)"
        echo "chaos[numeric]: ref  final row: $ref_row"
        echo "chaos[numeric]: $kind final row: $res_row"
        if [[ "$ref_row" != "$res_row" ]]; then
            echo "chaos[numeric]: FAIL — $kind recovery diverged from the pre-skip reference" >&2
            exit 1
        fi
    done

    echo "chaos[numeric]: OK — nan and spike injections at step $NUMSTEP recovered bit-identically"
    exit 0
fi

# ---------------------------------------------------------------- mp mode
NWORK=3
KILL_AT=10              # kill the victim once step dirs reach this index
HB=200                  # fast lease cadence so failover fits a smoke test
LT=1000
RUN="$WORK/mp_run"
mp_args=(--workers "$NWORK" --heartbeat-ms "$HB" --lease-timeout-ms "$LT")

echo "== chaos[mp]: uninterrupted in-process --workers $NWORK reference =="
"$BIN" "${common_args[@]}" --workers "$NWORK" --out "$WORK/ref_out"

echo "== chaos[mp]: dedicated coordinator + $NWORK workers on $RUN =="
# The coordinator must start FIRST: whoever creates the run store fixes
# the coordination mode (external vs elected), so workers wait for
# run.json before joining.
"$BIN" "${common_args[@]}" "${mp_args[@]}" --workers-external "$NWORK" \
    --run-dir "$RUN" --out "$WORK/mp_out" --worker-id coord &
COORD=$!

deadline=$((SECONDS + 60))
while [[ ! -f "$RUN/run.json" ]]; do
    if (( SECONDS >= deadline )); then
        echo "chaos[mp]: FAIL — coordinator never created $RUN/run.json" >&2
        exit 1
    fi
    sleep 0.05
done

declare -a WPID
for i in 0 1 2; do
    "$BIN" worker "${job_args[@]}" "${mp_args[@]}" \
        --run-dir "$RUN" --worker-id "w$i" &
    WPID[$i]=$!
done
VICTIM=${WPID[0]}

# wait until the exchange directory shows progress past KILL_AT, then
# kill -9 the victim (no cleanup — only lease expiry frees its shards)
deadline=$((SECONDS + 120))
while :; do
    max=-1
    for d in "$RUN"/grads/step_*; do
        [[ -d "$d" ]] || continue
        n=${d##*step_}
        n=$((10#$n))
        (( n > max )) && max=$n
    done
    (( max >= KILL_AT )) && break
    if ! kill -0 "$COORD" 2>/dev/null; then
        echo "chaos[mp]: FAIL — coordinator exited before step $KILL_AT" >&2
        exit 1
    fi
    if (( SECONDS >= deadline )); then
        echo "chaos[mp]: FAIL — no progress past step $KILL_AT within 120s" >&2
        exit 1
    fi
    sleep 0.05
done

if kill -9 "$VICTIM" 2>/dev/null; then
    echo "chaos[mp]: killed worker w0 (pid $VICTIM) at step dir $max"
else
    echo "chaos[mp]: WARN — w0 already exited before the kill window" >&2
fi
wait "$VICTIM" 2>/dev/null || true

echo "== chaos[mp]: relaunch w0 =="
"$BIN" worker "${job_args[@]}" "${mp_args[@]}" \
    --run-dir "$RUN" --worker-id w0 &
WPID[0]=$!

if ! wait "$COORD"; then
    echo "chaos[mp]: FAIL — coordinator exited nonzero" >&2
    exit 1
fi
echo "chaos[mp]: coordinator sealed the run"
for i in 0 1 2; do
    if ! wait "${WPID[$i]}"; then
        echo "chaos[mp]: FAIL — worker w$i exited nonzero" >&2
        exit 1
    fi
done

# every deterministic column of every step row must match the reference
cut -d, -f1-4 "$WORK/ref_out"/*__steps.csv > "$WORK/ref.cols"
cut -d, -f1-4 "$WORK/mp_out"/*__steps.csv  > "$WORK/mp.cols"
echo "chaos[mp]: ref final row: $(tail -n1 "$WORK/ref.cols")"
echo "chaos[mp]: mp  final row: $(tail -n1 "$WORK/mp.cols")"
if ! cmp -s "$WORK/ref.cols" "$WORK/mp.cols"; then
    echo "chaos[mp]: FAIL — multi-process run diverged from the in-process reference" >&2
    diff "$WORK/ref.cols" "$WORK/mp.cols" | head -20 >&2 || true
    exit 1
fi

echo "chaos[mp]: OK — kill -9 + relaunch converged bit-identically over $STEPS steps"
