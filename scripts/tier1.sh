#!/usr/bin/env bash
# Tier-1 verification: release build, full test suite, and a compile check
# of every bench target so benches can't silently rot.
#
#   scripts/tier1.sh           # build + test + bench --no-run
#   scripts/tier1.sh --fast    # skip the release build (debug test only)
#
# Exit codes: 0 ok, 2 toolchain missing, else the failing cargo status.

set -euo pipefail
cd "$(dirname "$0")/../rust"

if ! command -v cargo >/dev/null 2>&1; then
    echo "tier1: cargo not found on PATH — rust toolchain missing in this" >&2
    echo "tier1: environment; cannot verify (see ROADMAP.md 'Verification')" >&2
    exit 2
fi

if [[ "${1:-}" != "--fast" ]]; then
    echo "== cargo build --release =="
    cargo build --release
fi

echo "== cargo test -q =="
cargo test -q

echo "== cargo bench --no-run (bench targets must compile) =="
cargo bench --no-run

echo "tier1: OK"
