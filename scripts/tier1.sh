#!/usr/bin/env bash
# Tier-1 verification: release build, full test suite, and a compile check
# of every bench target so benches can't silently rot.
#
#   scripts/tier1.sh           # build + test + bench --no-run
#   scripts/tier1.sh --fast    # skip the release build (debug test only)
#
# When `cargo` is missing, scripts/toolchain.sh is invoked to bootstrap a
# pinned toolchain (rustup; needs network on first run).
#
# Exit codes: 0 ok, 2 toolchain missing and unbootstrappable, else the
# failing cargo status.

set -euo pipefail
SCRIPT_DIR="$(cd "$(dirname "$0")" && pwd)"
cd "$SCRIPT_DIR/../rust"

if ! command -v cargo >/dev/null 2>&1; then
    if TOOLDIR="$("$SCRIPT_DIR/toolchain.sh")"; then
        export PATH="$TOOLDIR:$PATH"
    fi
fi
if ! command -v cargo >/dev/null 2>&1; then
    echo "tier1: cargo not found and toolchain bootstrap failed — rust" >&2
    echo "tier1: toolchain missing; cannot verify (see ROADMAP.md)" >&2
    exit 2
fi

if [[ "${1:-}" != "--fast" ]]; then
    echo "== cargo build --release =="
    cargo build --release
fi

echo "== cargo test -q =="
cargo test -q

echo "== cargo bench --no-run (bench targets must compile) =="
cargo bench --no-run

echo "tier1: OK"
