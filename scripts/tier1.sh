#!/usr/bin/env bash
# Tier-1 verification: release build, full test suite, and a compile check
# of every bench target so benches can't silently rot.
#
#   scripts/tier1.sh               # build + test + bench --no-run
#   scripts/tier1.sh --fast        # skip the release build (debug test only)
#   scripts/tier1.sh --bench-diff  # additionally diff any fresh
#                                  # BENCH_*.json against bench/baselines/
#                                  # (no-op when benches haven't been run)
#
# When `cargo` is missing, scripts/toolchain.sh is invoked to bootstrap a
# pinned toolchain (rustup; needs network on first run).
#
# Exit codes: 0 ok, 2 toolchain missing and unbootstrappable, else the
# failing cargo status.

set -euo pipefail
SCRIPT_DIR="$(cd "$(dirname "$0")" && pwd)"

BENCH_DIFF=0
FAST=0
for arg in "$@"; do
    case "$arg" in
        --fast) FAST=1 ;;
        --bench-diff) BENCH_DIFF=1 ;;
        *) echo "tier1: unknown flag $arg" >&2; exit 64 ;;
    esac
done

cd "$SCRIPT_DIR/../rust"

if ! command -v cargo >/dev/null 2>&1; then
    if TOOLDIR="$("$SCRIPT_DIR/toolchain.sh")"; then
        export PATH="$TOOLDIR:$PATH"
    fi
fi
if ! command -v cargo >/dev/null 2>&1; then
    echo "tier1: cargo not found and toolchain bootstrap failed — rust" >&2
    echo "tier1: toolchain missing; cannot verify (see ROADMAP.md)" >&2
    exit 2
fi

if [[ $FAST -ne 1 ]]; then
    echo "== cargo build --release =="
    cargo build --release
fi

echo "== cargo test -q =="
cargo test -q

echo "== cargo bench --no-run (bench targets must compile) =="
cargo bench --no-run

if [[ $BENCH_DIFF -eq 1 ]]; then
    echo "== bench_diff (fresh BENCH_*.json vs bench/baselines) =="
    "$SCRIPT_DIR/bench_diff.sh"
fi

echo "tier1: OK"
