#!/usr/bin/env bash
# Tiered verification (tiers documented in ROADMAP.md §Verification tiers):
#
#   tier 0 (docs):   README.md + docs/ARCHITECTURE.md must exist (always)
#   tier 1 (rust):   release build, full test suite, bench compile check,
#                    cargo doc --no-deps with warnings denied
#   tier 2 (python): pytest over python/tests — runs INSTEAD when no rust
#                    toolchain can be found or bootstrapped, so the
#                    container always executes some tier of the suite
#   tier 3 (syntax): python compileall — last resort when pytest is
#                    missing too
#
#   scripts/tier1.sh               # build + test + bench --no-run
#   scripts/tier1.sh --fast        # skip the release build (debug test only)
#   scripts/tier1.sh --bench-diff  # additionally diff any fresh
#                                  # BENCH_*.json against bench/baselines/
#                                  # (no-op when benches haven't been run)
#   scripts/tier1.sh --chaos       # additionally run the crash/resume
#                                  # smoke loop (scripts/chaos.sh; no-op
#                                  # when cargo is absent)
#   scripts/tier1.sh --chaos-mp    # additionally run the multi-process
#                                  # kill -9/relaunch smoke loop
#                                  # (scripts/chaos.sh --mp; no-op when
#                                  # cargo is absent)
#   scripts/tier1.sh --chaos-numeric  # additionally run the numeric-fault
#                                  # smoke loop: PALLAS_NUMFAULT injection
#                                  # must recover via sentinel rollback
#                                  # (scripts/chaos.sh --numeric; no-op
#                                  # when cargo is absent)
#
# When `cargo` is missing, scripts/toolchain.sh is invoked to bootstrap a
# pinned toolchain (rustup; needs network on first run).
#
# Exit codes: 0 ok (tier noted in the final line), 2 no tier could run,
# else the failing cargo/pytest status.

set -euo pipefail
SCRIPT_DIR="$(cd "$(dirname "$0")" && pwd)"

BENCH_DIFF=0
CHAOS=0
CHAOS_MP=0
CHAOS_NUMERIC=0
FAST=0
for arg in "$@"; do
    case "$arg" in
        --fast) FAST=1 ;;
        --bench-diff) BENCH_DIFF=1 ;;
        --chaos) CHAOS=1 ;;
        --chaos-mp) CHAOS_MP=1 ;;
        --chaos-numeric) CHAOS_NUMERIC=1 ;;
        *) echo "tier1: unknown flag $arg" >&2; exit 64 ;;
    esac
done

# Docs check (every tier): the documentation layer is part of the
# contract — fail fast if it goes missing.
echo "== docs check (README.md, docs/ARCHITECTURE.md) =="
for doc in README.md docs/ARCHITECTURE.md; do
    if [[ ! -f "$SCRIPT_DIR/../$doc" ]]; then
        echo "tier1: missing $doc — the documentation layer is required" >&2
        exit 1
    fi
done
echo "docs present"

cd "$SCRIPT_DIR/../rust"

if ! command -v cargo >/dev/null 2>&1; then
    if TOOLDIR="$("$SCRIPT_DIR/toolchain.sh")"; then
        export PATH="$TOOLDIR:$PATH"
    fi
fi
if ! command -v cargo >/dev/null 2>&1; then
    echo "tier1: cargo not found and toolchain bootstrap failed" >&2
    if command -v python3 >/dev/null 2>&1; then
        cd "$SCRIPT_DIR/.."
        if python3 -c "import pytest" >/dev/null 2>&1; then
            echo "== tier 2 (python): pytest python/tests =="
            python3 -m pytest python/tests -q
            echo "tier1: rust tier SKIPPED (no toolchain — see ROADMAP.md); python tier OK"
        else
            echo "== tier 3 (syntax): python3 -m compileall python =="
            python3 -m compileall -q python
            echo "tier1: only a syntax check ran (no cargo, no pytest) — weakest tier"
        fi
        exit 0
    fi
    echo "tier1: no rust toolchain and no python3; cannot verify (see ROADMAP.md)" >&2
    exit 2
fi

if [[ $FAST -ne 1 ]]; then
    echo "== cargo build --release =="
    cargo build --release
fi

echo "== cargo test -q =="
cargo test -q

echo "== cargo bench --no-run (bench targets must compile) =="
cargo bench --no-run

echo "== cargo doc --no-deps (rustdoc links must not rot; warnings denied) =="
RUSTDOCFLAGS="-D warnings" cargo doc --no-deps --quiet

if [[ $BENCH_DIFF -eq 1 ]]; then
    echo "== bench_diff (fresh BENCH_*.json vs bench/baselines) =="
    "$SCRIPT_DIR/bench_diff.sh"
fi

if [[ $CHAOS -eq 1 ]]; then
    echo "== chaos (crash/resume smoke: PALLAS_FAULT kill + --resume) =="
    "$SCRIPT_DIR/chaos.sh"
fi

if [[ $CHAOS_MP -eq 1 ]]; then
    echo "== chaos-mp (multi-process smoke: kill -9 a worker + relaunch) =="
    "$SCRIPT_DIR/chaos.sh" --mp
fi

if [[ $CHAOS_NUMERIC -eq 1 ]]; then
    echo "== chaos-numeric (sentinel smoke: PALLAS_NUMFAULT + rollback) =="
    "$SCRIPT_DIR/chaos.sh" --numeric
fi

echo "tier1: OK"
