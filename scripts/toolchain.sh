#!/usr/bin/env bash
# Ensure a rust toolchain is available, bootstrapping a *pinned* one when
# `cargo` is absent, and print the directory containing `cargo` on stdout
# so callers can prepend it to PATH:
#
#   PATH="$(scripts/toolchain.sh):$PATH"
#
# Resolution order:
#   1. cargo already on PATH                 -> print its directory
#   2. a previous bootstrap in $CARGO_HOME   -> print that bin directory
#   3. rustup available                      -> install the pinned toolchain
#   4. curl available                        -> bootstrap rustup itself
#      (pinned toolchain, minimal profile), then as 3
#
# Pin with RUST_TOOLCHAIN=<version> for reproducible CI runs; all
# diagnostics go to stderr so stdout stays a clean path.
#
# Exit codes: 0 ok (cargo bin dir on stdout), 2 no toolchain and no way to
# obtain one (offline container without rustup — see ROADMAP.md).

set -euo pipefail

PIN="${RUST_TOOLCHAIN:-1.82.0}"
RUSTUP_URL="https://sh.rustup.rs"
CARGO_BIN="${CARGO_HOME:-$HOME/.cargo}/bin"

say() { echo "toolchain: $*" >&2; }

if command -v cargo >/dev/null 2>&1; then
    dirname "$(command -v cargo)"
    exit 0
fi

if [[ -x "$CARGO_BIN/cargo" ]]; then
    echo "$CARGO_BIN"
    exit 0
fi

if ! command -v rustup >/dev/null 2>&1; then
    if ! command -v curl >/dev/null 2>&1; then
        say "no cargo, no rustup, no curl — cannot bootstrap a toolchain"
        exit 2
    fi
    say "no cargo/rustup on PATH; bootstrapping rustup with pinned toolchain $PIN"
    if ! curl --proto '=https' --tlsv1.2 -sSf --max-time 120 "$RUSTUP_URL" \
        | sh -s -- -y --no-modify-path --profile minimal --default-toolchain "$PIN" >&2; then
        say "rustup bootstrap failed (offline container?)"
        exit 2
    fi
fi

RUSTUP="$(command -v rustup 2>/dev/null || echo "$CARGO_BIN/rustup")"
if ! "$RUSTUP" toolchain install "$PIN" --profile minimal >&2; then
    say "pinned toolchain $PIN install failed"
    exit 2
fi

# Scope the pin to this invocation: print the pinned toolchain's own bin
# directory rather than flipping the user's machine-wide rustup default.
TOOLCHAIN_CARGO="$("$RUSTUP" which --toolchain "$PIN" cargo 2>/dev/null || true)"
if [[ -n "$TOOLCHAIN_CARGO" && -x "$TOOLCHAIN_CARGO" ]]; then
    dirname "$TOOLCHAIN_CARGO"
    exit 0
fi
# Shim fallback: a fresh rustup-init bootstrap above already made $PIN the
# default of its brand-new $CARGO_HOME (no preexisting default to clobber).
if [[ -x "$CARGO_BIN/cargo" ]]; then
    echo "$CARGO_BIN"
    exit 0
fi
say "bootstrap finished but no usable cargo found for toolchain $PIN"
exit 2
